//! Linear and logistic regression over a local data shard, with minibatch
//! stochastic gradients. These are the workhorses of the figure benches:
//! smooth, fast, and their heterogeneity across shards is set directly by
//! the data generator ([`crate::data`]).

use super::GradientModel;
use crate::linalg::vecops;
use crate::util::rng::Pcg64;

/// A shard of supervised data: row-major features `x[row*dim..]` and one
/// target per row.
#[derive(Debug, Clone)]
pub struct Shard {
    pub dim: usize,
    pub features: Vec<f32>,
    pub targets: Vec<f32>,
}

impl Shard {
    pub fn rows(&self) -> usize {
        self.targets.len()
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.features[r * self.dim..(r + 1) * self.dim]
    }

    pub fn validate(&self) {
        assert_eq!(self.features.len(), self.dim * self.targets.len());
        assert!(self.rows() > 0, "empty shard");
    }
}

/// ½ mean squared error linear regression: f(w) = 1/(2m) Σ (⟨a_r, w⟩ − b_r)².
#[derive(Debug, Clone)]
pub struct LinearRegression {
    pub shard: Shard,
    pub batch: usize,
    /// L2 regularization (adds λ‖w‖²/2; keeps the Hessian well-conditioned).
    pub l2: f32,
}

impl LinearRegression {
    pub fn new(shard: Shard, batch: usize) -> LinearRegression {
        shard.validate();
        assert!(batch >= 1);
        LinearRegression { shard, batch, l2: 0.0 }
    }

    pub fn with_l2(mut self, l2: f32) -> LinearRegression {
        self.l2 = l2;
        self
    }

    fn residual(&self, x: &[f32], r: usize) -> f32 {
        vecops::dot(self.shard.row(r), x) as f32 - self.shard.targets[r]
    }
}

impl GradientModel for LinearRegression {
    fn dim(&self) -> usize {
        self.shard.dim
    }

    /// The weight vector has no matrix structure: fold near-square for
    /// the low-rank codecs.
    fn shape_manifest(&self) -> super::ShapeManifest {
        super::ShapeManifest::folded(self.dim())
    }

    fn stoch_grad(&mut self, x: &[f32], out: &mut [f32], rng: &mut Pcg64) -> f64 {
        out.fill(0.0);
        let m = self.shard.rows();
        let mut loss = 0.0f64;
        for _ in 0..self.batch {
            let r = rng.below(m as u64) as usize;
            let e = self.residual(x, r);
            loss += 0.5 * (e as f64) * (e as f64);
            vecops::axpy(e / self.batch as f32, self.shard.row(r), out);
        }
        if self.l2 > 0.0 {
            vecops::axpy(self.l2, x, out);
            loss += 0.5 * self.l2 as f64 * vecops::dot(x, x);
        }
        loss / self.batch as f64
    }

    fn full_loss(&self, x: &[f32]) -> f64 {
        let m = self.shard.rows();
        let mut loss = 0.0f64;
        for r in 0..m {
            let e = self.residual(x, r) as f64;
            loss += 0.5 * e * e;
        }
        loss / m as f64 + 0.5 * self.l2 as f64 * vecops::dot(x, x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let m = self.shard.rows();
        for r in 0..m {
            let e = self.residual(x, r);
            vecops::axpy(e / m as f32, self.shard.row(r), out);
        }
        if self.l2 > 0.0 {
            vecops::axpy(self.l2, x, out);
        }
    }
}

/// Binary logistic regression with ±1 targets:
/// f(w) = 1/m Σ log(1 + exp(−b_r ⟨a_r, w⟩)).
#[derive(Debug, Clone)]
pub struct LogisticRegression {
    pub shard: Shard,
    pub batch: usize,
    pub l2: f32,
}

impl LogisticRegression {
    pub fn new(shard: Shard, batch: usize) -> LogisticRegression {
        shard.validate();
        assert!(
            shard.targets.iter().all(|&t| t == 1.0 || t == -1.0),
            "logistic targets must be ±1"
        );
        LogisticRegression { shard, batch, l2: 1e-4 }
    }

    /// σ(−b·⟨a,w⟩) — the weight on the gradient of one example.
    fn margin_sigmoid(&self, x: &[f32], r: usize) -> (f32, f64) {
        let b = self.shard.targets[r];
        let m = b * vecops::dot(self.shard.row(r), x) as f32;
        // Numerically stable log(1+exp(−m)) and σ(−m).
        let loss = if m > 0.0 {
            ((-m).exp() as f64).ln_1p()
        } else {
            -m as f64 + (m.exp() as f64).ln_1p()
        };
        let s = 1.0 / (1.0 + m.exp()); // σ(−m)
        (b * s, loss)
    }
}

impl GradientModel for LogisticRegression {
    fn dim(&self) -> usize {
        self.shard.dim
    }

    /// The weight vector has no matrix structure: fold near-square for
    /// the low-rank codecs.
    fn shape_manifest(&self) -> super::ShapeManifest {
        super::ShapeManifest::folded(self.dim())
    }

    fn stoch_grad(&mut self, x: &[f32], out: &mut [f32], rng: &mut Pcg64) -> f64 {
        out.fill(0.0);
        let m = self.shard.rows();
        let mut loss = 0.0f64;
        for _ in 0..self.batch {
            let r = rng.below(m as u64) as usize;
            let (w, l) = self.margin_sigmoid(x, r);
            loss += l;
            vecops::axpy(-w / self.batch as f32, self.shard.row(r), out);
        }
        vecops::axpy(self.l2, x, out);
        loss / self.batch as f64 + 0.5 * self.l2 as f64 * vecops::dot(x, x)
    }

    fn full_loss(&self, x: &[f32]) -> f64 {
        let m = self.shard.rows();
        let loss: f64 = (0..m).map(|r| self.margin_sigmoid(x, r).1).sum();
        loss / m as f64 + 0.5 * self.l2 as f64 * vecops::dot(x, x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        out.fill(0.0);
        let m = self.shard.rows();
        for r in 0..m {
            let (w, _) = self.margin_sigmoid(x, r);
            vecops::axpy(-w / m as f32, self.shard.row(r), out);
        }
        vecops::axpy(self.l2, x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::grad_check;

    fn toy_shard() -> Shard {
        Shard {
            dim: 3,
            features: vec![
                1.0, 0.0, 0.5, //
                0.0, 1.0, -0.5, //
                1.0, 1.0, 0.0, //
                -1.0, 0.5, 1.0,
            ],
            targets: vec![1.0, -1.0, 1.0, -1.0],
        }
    }

    #[test]
    fn linreg_grad_check() {
        let m = LinearRegression::new(toy_shard(), 2).with_l2(0.01);
        grad_check(&m, &[0.2, -0.4, 0.9], 2e-3);
    }

    #[test]
    fn logreg_grad_check() {
        let m = LogisticRegression::new(toy_shard(), 2);
        grad_check(&m, &[0.2, -0.4, 0.9], 2e-3);
    }

    #[test]
    fn linreg_exact_solution_has_zero_grad() {
        // y = 2*x0 - x1 exactly.
        let shard = Shard {
            dim: 2,
            features: vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0, 2.0, -1.0],
            targets: vec![2.0, -1.0, 1.0, 5.0],
        };
        let m = LinearRegression::new(shard, 1);
        let mut g = vec![0.0f32; 2];
        m.full_grad(&[2.0, -1.0], &mut g);
        assert!(g.iter().all(|v| v.abs() < 1e-5), "{g:?}");
        assert!(m.full_loss(&[2.0, -1.0]) < 1e-10);
    }

    #[test]
    fn stoch_grad_unbiased_estimates_full_grad() {
        let mut m = LinearRegression::new(toy_shard(), 1);
        let x = [0.5f32, 0.5, -0.5];
        let mut full = vec![0.0f32; 3];
        m.full_grad(&x, &mut full);
        let mut acc = vec![0.0f64; 3];
        let mut g = vec![0.0f32; 3];
        let mut rng = Pcg64::seed_from_u64(5);
        let trials = 40_000;
        for _ in 0..trials {
            m.stoch_grad(&x, &mut g, &mut rng);
            for (a, v) in acc.iter_mut().zip(&g) {
                *a += *v as f64;
            }
        }
        for (f, a) in full.iter().zip(&acc) {
            assert!((a / trials as f64 - *f as f64).abs() < 0.02);
        }
    }

    #[test]
    fn logreg_loss_decreases_along_negative_gradient() {
        let m = LogisticRegression::new(toy_shard(), 4);
        let x = vec![0.1f32, 0.1, 0.1];
        let mut g = vec![0.0f32; 3];
        m.full_grad(&x, &mut g);
        let mut x2 = x.clone();
        vecops::axpy(-0.1, &g, &mut x2);
        assert!(m.full_loss(&x2) < m.full_loss(&x));
    }

    #[test]
    #[should_panic(expected = "±1")]
    fn logreg_rejects_bad_targets() {
        let mut s = toy_shard();
        s.targets[0] = 0.5;
        LogisticRegression::new(s, 1);
    }

    #[test]
    #[should_panic(expected = "empty shard")]
    fn rejects_empty_shard() {
        LinearRegression::new(
            Shard {
                dim: 2,
                features: vec![],
                targets: vec![],
            },
            1,
        );
    }
}
