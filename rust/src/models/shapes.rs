//! Tensor shape manifests: the bridge between the flat parameter vector
//! x ∈ R^N that every algorithm and codec moves around and the *tensor*
//! structure the model documents but never exposed — `[W1 (h×d) | b1 |
//! W2 (k×h) | b2]` for the MLP, a near-square fold for the vector models.
//!
//! A [`ShapeManifest`] is a partition of `0..N` into row-major matrix and
//! vector segments, in layout order. [`ShapeManifest::views`] hands back
//! **zero-copy** slices into the flat buffer (pinned by a property test:
//! `flatten(views(x)) == x`, pointer-identical, no copies), which is what
//! lets the low-rank link compressors ([`crate::compression::LowRank`])
//! run power iterations directly on the wire-bound vector.
//!
//! Vector models (quadratic, linear/logistic regression) get the
//! [`ShapeManifest::folded`] manifest: the length-N vector reshaped
//! row-major into the largest ⌊√N⌋ × (N / ⌊√N⌋) matrix, with the
//! remainder as a trailing vector segment (sent full precision by the
//! low-rank codec). This is the standard PowerGossip/PowerSGD treatment
//! of non-matrix parameters.

/// One segment of the flat parameter vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorShape {
    /// A row-major `rows × cols` matrix.
    Matrix { rows: usize, cols: usize },
    /// A plain vector (biases, folding remainders).
    Vector { len: usize },
}

impl TensorShape {
    /// Flat elements this segment occupies.
    pub fn len(&self) -> usize {
        match *self {
            TensorShape::Matrix { rows, cols } => rows * cols,
            TensorShape::Vector { len } => len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A read-only zero-copy view of one segment.
#[derive(Debug, Clone, Copy)]
pub enum TensorView<'a> {
    Matrix {
        /// Row-major `rows × cols` data, a direct slice of the flat vector.
        data: &'a [f32],
        rows: usize,
        cols: usize,
    },
    Vector { data: &'a [f32] },
}

impl<'a> TensorView<'a> {
    /// The underlying flat slice (row-major for matrices).
    pub fn data(&self) -> &'a [f32] {
        match self {
            TensorView::Matrix { data, .. } => data,
            TensorView::Vector { data } => data,
        }
    }
}

/// A mutable zero-copy view of one segment.
#[derive(Debug)]
pub enum TensorViewMut<'a> {
    Matrix {
        data: &'a mut [f32],
        rows: usize,
        cols: usize,
    },
    Vector { data: &'a mut [f32] },
}

/// Ordered partition of a flat parameter vector into tensor segments.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShapeManifest {
    pub tensors: Vec<TensorShape>,
}

/// ⌊√n⌋ without float-rounding surprises.
fn isqrt(n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    let mut r = (n as f64).sqrt() as usize;
    while (r + 1) * (r + 1) <= n {
        r += 1;
    }
    while r * r > n {
        r -= 1;
    }
    r
}

impl ShapeManifest {
    /// A single vector segment — the trivial manifest (no matrix
    /// structure; low-rank codecs pass it through full precision).
    pub fn flat(len: usize) -> ShapeManifest {
        ShapeManifest {
            tensors: vec![TensorShape::Vector { len }],
        }
    }

    /// Fold a length-`len` vector into the largest near-square row-major
    /// matrix `⌊√len⌋ × (len / ⌊√len⌋)`, with the division remainder as a
    /// trailing full-precision vector. `folded(0)` is the empty manifest.
    pub fn folded(len: usize) -> ShapeManifest {
        let rows = isqrt(len);
        if rows == 0 {
            return ShapeManifest { tensors: Vec::new() };
        }
        let cols = len / rows;
        let tail = len - rows * cols;
        let mut tensors = vec![TensorShape::Matrix { rows, cols }];
        if tail > 0 {
            tensors.push(TensorShape::Vector { len: tail });
        }
        ShapeManifest { tensors }
    }

    /// The one-hidden-layer MLP layout ([`crate::models::Mlp`]):
    /// `[W1 (h×d) | b1 (h) | W2 (k×h) | b2 (k)]`, all row-major.
    pub fn mlp(d: usize, h: usize, k: usize) -> ShapeManifest {
        ShapeManifest {
            tensors: vec![
                TensorShape::Matrix { rows: h, cols: d },
                TensorShape::Vector { len: h },
                TensorShape::Matrix { rows: k, cols: h },
                TensorShape::Vector { len: k },
            ],
        }
    }

    /// Total flat length covered by the manifest.
    pub fn total_len(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// `(offset, shape)` per segment, in layout order.
    pub fn segments(&self) -> Vec<(usize, TensorShape)> {
        let mut out = Vec::with_capacity(self.tensors.len());
        let mut off = 0;
        for &t in &self.tensors {
            out.push((off, t));
            off += t.len();
        }
        out
    }

    /// Zero-copy views over `x` (one slice per segment, in order; the
    /// concatenation of the views *is* `x`). Panics when `x.len()` does
    /// not match [`ShapeManifest::total_len`].
    pub fn views<'a>(&self, x: &'a [f32]) -> Vec<TensorView<'a>> {
        assert_eq!(x.len(), self.total_len(), "manifest/vector length mismatch");
        let mut out = Vec::with_capacity(self.tensors.len());
        let mut off = 0;
        for &t in &self.tensors {
            let data = &x[off..off + t.len()];
            off += t.len();
            out.push(match t {
                TensorShape::Matrix { rows, cols } => TensorView::Matrix { data, rows, cols },
                TensorShape::Vector { .. } => TensorView::Vector { data },
            });
        }
        out
    }

    /// Mutable zero-copy views over `x` (disjoint via `split_at_mut`).
    pub fn views_mut<'a>(&self, x: &'a mut [f32]) -> Vec<TensorViewMut<'a>> {
        assert_eq!(x.len(), self.total_len(), "manifest/vector length mismatch");
        let mut out = Vec::with_capacity(self.tensors.len());
        let mut rest = x;
        for &t in &self.tensors {
            let (data, tail) = rest.split_at_mut(t.len());
            rest = tail;
            out.push(match t {
                TensorShape::Matrix { rows, cols } => TensorViewMut::Matrix { data, rows, cols },
                TensorShape::Vector { .. } => TensorViewMut::Vector { data },
            });
        }
        out
    }

    /// f32 elements a rank-`rank` factorization of this manifest ships:
    /// each matrix contributes `r_eff·(rows + cols)` (the P̂ and Q
    /// factors, `r_eff = min(rank, rows, cols)`); vector segments ride
    /// full precision. This is the exact element count behind
    /// [`crate::compression::LowRank`]'s `wire_bytes`.
    pub fn lowrank_floats(&self, rank: usize) -> usize {
        self.tensors
            .iter()
            .map(|t| match *t {
                TensorShape::Matrix { rows, cols } => {
                    let r_eff = rank.min(rows).min(cols);
                    r_eff * (rows + cols)
                }
                TensorShape::Vector { len } => len,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_exact_on_squares_and_neighbors() {
        for n in [0usize, 1, 2, 3, 4, 8, 9, 15, 16, 17, 1023, 1024, 1025, 16384] {
            let r = isqrt(n);
            assert!(r * r <= n, "isqrt({n}) = {r}");
            assert!((r + 1) * (r + 1) > n, "isqrt({n}) = {r}");
        }
    }

    #[test]
    fn folded_covers_exactly_with_tail() {
        for len in [1usize, 7, 64, 128, 1024, 16384] {
            let m = ShapeManifest::folded(len);
            assert_eq!(m.total_len(), len, "folded({len})");
            match m.tensors[0] {
                TensorShape::Matrix { rows, cols } => {
                    assert_eq!(rows, isqrt(len));
                    assert_eq!(cols, len / rows);
                }
                _ => panic!("folded manifest must lead with a matrix"),
            }
        }
        // 128 = 11×11 + 7-tail; 1024 and 16384 fold square with no tail.
        assert_eq!(ShapeManifest::folded(128).tensors.len(), 2);
        assert_eq!(ShapeManifest::folded(1024).tensors.len(), 1);
        assert_eq!(ShapeManifest::folded(16384).tensors.len(), 1);
    }

    #[test]
    fn mlp_manifest_matches_param_dim() {
        let (d, h, k) = (17, 32, 4);
        let m = ShapeManifest::mlp(d, h, k);
        assert_eq!(m.total_len(), crate::models::Mlp::param_dim(d, h, k));
        assert_eq!(m.tensors.len(), 4);
    }

    #[test]
    fn views_are_zero_copy_and_cover_in_order() {
        let m = ShapeManifest::mlp(3, 4, 2);
        let x: Vec<f32> = (0..m.total_len()).map(|i| i as f32).collect();
        let views = m.views(&x);
        let mut off = 0;
        for v in &views {
            let data = v.data();
            // Pointer identity: the view *is* the flat buffer's memory.
            assert!(std::ptr::eq(data.as_ptr(), x[off..].as_ptr()));
            off += data.len();
        }
        assert_eq!(off, x.len());
    }

    #[test]
    fn views_mut_cover_disjointly() {
        let m = ShapeManifest::folded(67); // 8×8 matrix + 3-tail
        let mut x = vec![0.0f32; 67];
        for (i, v) in m.views_mut(&mut x).into_iter().enumerate() {
            match v {
                TensorViewMut::Matrix { data, .. } | TensorViewMut::Vector { data } => {
                    data.fill(i as f32 + 1.0);
                }
            }
        }
        assert!(x[..64].iter().all(|v| *v == 1.0));
        assert!(x[64..].iter().all(|v| *v == 2.0));
    }

    #[test]
    fn lowrank_floats_closed_form() {
        // 32×32 fold at rank 4: 4·(32+32) = 256 floats of 1024 — 25%.
        assert_eq!(ShapeManifest::folded(1024).lowrank_floats(4), 256);
        // 128×128 fold at rank 4: 4·256 = 1024 floats of 16384 — 6.25%.
        assert_eq!(ShapeManifest::folded(16384).lowrank_floats(4), 1024);
        // Rank clamps at min(rows, cols); tails ride full precision.
        let m = ShapeManifest::folded(67); // 8×8 + 3
        assert_eq!(m.lowrank_floats(100), 8 * (8 + 8) + 3);
        // MLP: biases full precision.
        let mlp = ShapeManifest::mlp(64, 32, 4);
        assert_eq!(mlp.lowrank_floats(2), 2 * (32 + 64) + 32 + 2 * (4 + 32) + 4);
    }
}
