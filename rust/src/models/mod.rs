//! Differentiable models for the L3-native training paths.
//!
//! The paper's theory is model-agnostic — it needs only L-smooth local
//! objectives f_i with bounded gradient variance (σ within a node, ζ
//! across nodes). For the figure-regeneration benches we therefore use
//! fast rust-native models (quadratic, linear/logistic regression, a small
//! MLP with manual backprop) over synthetic heterogeneous shards; the
//! end-to-end example swaps in the JAX transformer through
//! [`crate::runtime`] behind the same trait.

pub mod linear;
mod mlp;
mod quadratic;
pub mod shapes;

pub use linear::{LinearRegression, LogisticRegression, Shard};
pub use mlp::Mlp;
pub use quadratic::Quadratic;
pub use shapes::{ShapeManifest, TensorShape, TensorView, TensorViewMut};

use crate::util::rng::Pcg64;

/// A node-local differentiable objective f_i. One instance per worker,
/// owning that worker's data shard. `Send` so workers can move to threads.
pub trait GradientModel: Send {
    /// Parameter dimension N.
    fn dim(&self) -> usize;

    /// Tensor structure of the flat parameter vector — what the low-rank
    /// link compressors factorize ([`ShapeManifest`]). Vector models fold
    /// into a near-square matrix by default; structured models (the MLP)
    /// override with their true layer layout.
    fn shape_manifest(&self) -> ShapeManifest {
        ShapeManifest::folded(self.dim())
    }

    /// Sample a minibatch ξ and write ∇F_i(x; ξ) into `out`; returns the
    /// minibatch loss F_i(x; ξ).
    fn stoch_grad(&mut self, x: &[f32], out: &mut [f32], rng: &mut Pcg64) -> f64;

    /// Deterministic loss f_i(x) over the full local shard.
    fn full_loss(&self, x: &[f32]) -> f64;

    /// Deterministic gradient ∇f_i(x) over the full local shard.
    fn full_grad(&self, x: &[f32], out: &mut [f32]);
}

/// Finite-difference gradient check used by each model's tests.
#[cfg(test)]
pub(crate) fn grad_check<M: GradientModel>(model: &M, x: &[f32], tol: f64) {
    let n = model.dim();
    let mut g = vec![0.0f32; n];
    model.full_grad(x, &mut g);
    let eps = 1e-3f32;
    for i in 0..n {
        let mut xp = x.to_vec();
        let mut xm = x.to_vec();
        xp[i] += eps;
        xm[i] -= eps;
        let fd = (model.full_loss(&xp) - model.full_loss(&xm)) / (2.0 * eps as f64);
        let err = (fd - g[i] as f64).abs() / (1.0 + fd.abs());
        assert!(
            err < tol,
            "grad check failed at coord {i}: analytic {} vs fd {fd} (rel {err})",
            g[i]
        );
    }
}
