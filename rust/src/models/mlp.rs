//! One-hidden-layer MLP classifier with manual backprop — the non-convex
//! stand-in for the paper's ResNet-20 in the figure benches (the theory
//! only needs L-smoothness, which tanh + softmax-CE satisfies).
//!
//! Flat parameter layout (matching the paper's x ∈ R^N view and the L2
//! transformer's flat vector): `[W1 (h×d) | b1 (h) | W2 (k×h) | b2 (k)]`,
//! all row-major.

use super::linear::Shard;
use super::GradientModel;
use crate::linalg::vecops;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct Mlp {
    pub shard: Shard,
    pub hidden: usize,
    pub classes: usize,
    pub batch: usize,
    pub l2: f32,
    // Scratch buffers reused across calls (no allocation on the hot loop).
    scratch_h: Vec<f32>,
    scratch_p: Vec<f32>,
}

impl Mlp {
    pub fn new(shard: Shard, hidden: usize, classes: usize, batch: usize) -> Mlp {
        shard.validate();
        assert!(classes >= 2);
        assert!(shard
            .targets
            .iter()
            .all(|&t| t >= 0.0 && t.fract() == 0.0 && (t as usize) < classes));
        Mlp {
            shard,
            hidden,
            classes,
            batch,
            l2: 1e-4,
            scratch_h: vec![0.0; hidden],
            scratch_p: vec![0.0; classes],
        }
    }

    pub fn param_dim(d: usize, h: usize, k: usize) -> usize {
        h * d + h + k * h + k
    }

    /// Xavier-style initial parameter vector (shared across nodes so all
    /// workers start from the same x_1, as the algorithms require).
    pub fn init_params(d: usize, h: usize, k: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0x1417);
        let n = Self::param_dim(d, h, k);
        let mut x = vec![0.0f32; n];
        let s1 = (2.0 / (d + h) as f32).sqrt();
        let s2 = (2.0 / (h + k) as f32).sqrt();
        rng.fill_normal_f32(&mut x[..h * d], 0.0, s1);
        let w2_start = h * d + h;
        rng.fill_normal_f32(&mut x[w2_start..w2_start + k * h], 0.0, s2);
        x
    }

    /// Forward + backward on one example; accumulates grad into `out`
    /// scaled by `gscale`; returns CE loss. `x` is the flat param vector.
    fn example_grad(
        &mut self,
        x: &[f32],
        row: usize,
        out: Option<&mut [f32]>,
        gscale: f32,
    ) -> f64 {
        let (d, h, k) = (self.shard.dim, self.hidden, self.classes);
        let (w1, rest) = x.split_at(h * d);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(k * h);

        let a = self.shard.row(row).to_vec(); // input
        let label = self.shard.targets[row] as usize;

        // Hidden: z1 = W1 a + b1; act = tanh(z1).
        let hbuf = &mut self.scratch_h;
        for j in 0..h {
            hbuf[j] = (vecops::dot(&w1[j * d..(j + 1) * d], &a) as f32 + b1[j]).tanh();
        }
        // Logits: z2 = W2 act + b2; softmax.
        let pbuf = &mut self.scratch_p;
        for c in 0..k {
            pbuf[c] = vecops::dot(&w2[c * h..(c + 1) * h], hbuf) as f32 + b2[c];
        }
        let maxl = pbuf.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let mut zsum = 0.0f64;
        for p in pbuf.iter_mut() {
            *p = (*p - maxl).exp();
            zsum += *p as f64;
        }
        for p in pbuf.iter_mut() {
            *p = (*p as f64 / zsum) as f32;
        }
        let loss = -(pbuf[label].max(1e-30) as f64).ln();

        if let Some(out) = out {
            // dL/dz2 = p − onehot(label).
            let mut dz2 = pbuf.clone();
            dz2[label] -= 1.0;
            // Grad W2, b2; backprop into hidden.
            let mut dh = vec![0.0f32; h];
            let (gw1, grest) = out.split_at_mut(h * d);
            let (gb1, grest) = grest.split_at_mut(h);
            let (gw2, gb2) = grest.split_at_mut(k * h);
            for c in 0..k {
                let g = dz2[c] * gscale;
                vecops::axpy(g, hbuf, &mut gw2[c * h..(c + 1) * h]);
                gb2[c] += g;
                vecops::axpy(dz2[c], &w2[c * h..(c + 1) * h], &mut dh);
            }
            // Through tanh: dz1 = dh ⊙ (1 − act²).
            for j in 0..h {
                let dz1 = dh[j] * (1.0 - hbuf[j] * hbuf[j]) * gscale;
                vecops::axpy(dz1, &a, &mut gw1[j * d..(j + 1) * d]);
                gb1[j] += dz1;
            }
        }
        loss
    }
}

impl GradientModel for Mlp {
    fn dim(&self) -> usize {
        Self::param_dim(self.shard.dim, self.hidden, self.classes)
    }

    /// The documented flat layout, now exposed as tensors:
    /// `[W1 (h×d) | b1 | W2 (k×h) | b2]` — weight matrices factorize under
    /// the low-rank codecs, biases ride full precision.
    fn shape_manifest(&self) -> super::ShapeManifest {
        super::ShapeManifest::mlp(self.shard.dim, self.hidden, self.classes)
    }

    fn stoch_grad(&mut self, x: &[f32], out: &mut [f32], rng: &mut Pcg64) -> f64 {
        assert_eq!(x.len(), self.dim());
        out.fill(0.0);
        let m = self.shard.rows();
        let mut loss = 0.0;
        let scale = 1.0 / self.batch as f32;
        for _ in 0..self.batch {
            let r = rng.below(m as u64) as usize;
            loss += self.example_grad(x, r, Some(out), scale);
        }
        vecops::axpy(self.l2, x, out);
        loss / self.batch as f64 + 0.5 * self.l2 as f64 * vecops::dot(x, x)
    }

    fn full_loss(&self, x: &[f32]) -> f64 {
        // `example_grad` needs &mut self for scratch; clone the scratch
        // path cheaply by making a local mutable copy of the buffers.
        let mut me = self.clone();
        let m = self.shard.rows();
        let loss: f64 = (0..m).map(|r| me.example_grad(x, r, None, 0.0)).sum();
        loss / m as f64 + 0.5 * self.l2 as f64 * vecops::dot(x, x)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        let mut me = self.clone();
        out.fill(0.0);
        let m = self.shard.rows();
        let scale = 1.0 / m as f32;
        for r in 0..m {
            me.example_grad(x, r, Some(out), scale);
        }
        vecops::axpy(self.l2, x, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::grad_check;

    fn toy_shard() -> Shard {
        Shard {
            dim: 2,
            features: vec![
                1.0, 0.0, //
                0.0, 1.0, //
                -1.0, 0.0, //
                0.0, -1.0, //
                0.7, 0.7,
            ],
            targets: vec![0.0, 1.0, 2.0, 1.0, 0.0],
        }
    }

    #[test]
    fn param_dim_formula() {
        assert_eq!(Mlp::param_dim(2, 4, 3), 8 + 4 + 12 + 3);
    }

    #[test]
    fn grad_check_mlp() {
        let m = Mlp::new(toy_shard(), 4, 3, 1);
        let x = Mlp::init_params(2, 4, 3, 7);
        grad_check(&m, &x, 5e-3);
    }

    #[test]
    fn loss_is_log_k_at_init_with_zero_weights() {
        let m = Mlp::new(toy_shard(), 4, 3, 1);
        let x = vec![0.0f32; m.dim()];
        let loss = m.full_loss(&x);
        assert!((loss - (3.0f64).ln()).abs() < 1e-6, "loss {loss}");
    }

    #[test]
    fn sgd_reduces_loss() {
        let mut m = Mlp::new(toy_shard(), 8, 3, 5);
        let mut x = Mlp::init_params(2, 8, 3, 11);
        let mut rng = Pcg64::seed_from_u64(12);
        let mut g = vec![0.0f32; m.dim()];
        let initial = m.full_loss(&x);
        for _ in 0..300 {
            m.stoch_grad(&x, &mut g, &mut rng);
            vecops::axpy(-0.5, &g, &mut x);
        }
        let fin = m.full_loss(&x);
        assert!(fin < 0.5 * initial, "{initial} -> {fin}");
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_labels() {
        let mut s = toy_shard();
        s.targets[0] = 5.0;
        Mlp::new(s, 4, 3, 1);
    }

    #[test]
    fn init_params_deterministic_by_seed() {
        let a = Mlp::init_params(3, 5, 2, 9);
        let b = Mlp::init_params(3, 5, 2, 9);
        assert_eq!(a, b);
        let c = Mlp::init_params(3, 5, 2, 10);
        assert_ne!(a, c);
    }
}
