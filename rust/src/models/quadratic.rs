//! Heterogeneous quadratic objective — the analytically solvable testbed.
//!
//! Node i owns f_i(x) = ½‖x − c_i‖², so f(x) = (1/n)Σ f_i has the unique
//! optimum x* = mean(c_i), L = 1, and the inter-node variation ζ² equals
//! the variance of the centers. Stochastic gradients add N(0, σ²/N)
//! noise per coordinate, giving exact control of the σ in Assumption 1.4.
//! Every convergence test in the algorithm suite checks against this
//! model's closed form.

use super::GradientModel;
use crate::util::rng::Pcg64;

#[derive(Debug, Clone)]
pub struct Quadratic {
    /// Center c_i of this node's objective.
    pub center: Vec<f32>,
    /// Per-coordinate stochastic-gradient noise std (σ/√N per coord).
    pub noise_std: f32,
}

impl Quadratic {
    pub fn new(center: Vec<f32>, noise_std: f32) -> Quadratic {
        Quadratic { center, noise_std }
    }

    /// Build one Quadratic per node with centers drawn N(0, spread²) —
    /// `spread` directly sets ζ.
    pub fn family(
        n_nodes: usize,
        dim: usize,
        spread: f32,
        noise_std: f32,
        seed: u64,
    ) -> Vec<Quadratic> {
        (0..n_nodes)
            .map(|i| {
                let mut rng = Pcg64::new(seed, i as u64);
                let mut c = vec![0.0f32; dim];
                rng.fill_normal_f32(&mut c, 0.0, spread);
                Quadratic::new(c, noise_std)
            })
            .collect()
    }

    /// The global optimum of the averaged family.
    pub fn optimum(family: &[Quadratic]) -> Vec<f32> {
        let dim = family[0].center.len();
        let mut x = vec![0.0f32; dim];
        for q in family {
            crate::linalg::vecops::axpy(1.0, &q.center, &mut x);
        }
        crate::linalg::vecops::scale(1.0 / family.len() as f32, &mut x);
        x
    }
}

impl GradientModel for Quadratic {
    fn dim(&self) -> usize {
        self.center.len()
    }

    /// No inherent matrix structure: the parameter vector folds into the
    /// near-square matrix the low-rank codecs need.
    fn shape_manifest(&self) -> super::ShapeManifest {
        super::ShapeManifest::folded(self.dim())
    }

    fn stoch_grad(&mut self, x: &[f32], out: &mut [f32], rng: &mut Pcg64) -> f64 {
        assert_eq!(x.len(), self.dim());
        for ((o, xi), ci) in out.iter_mut().zip(x).zip(&self.center) {
            let noise = if self.noise_std > 0.0 {
                rng.normal_with(0.0, self.noise_std as f64) as f32
            } else {
                0.0
            };
            *o = (xi - ci) + noise;
        }
        self.full_loss(x)
    }

    fn full_loss(&self, x: &[f32]) -> f64 {
        0.5 * crate::linalg::vecops::dist2_sq(x, &self.center)
    }

    fn full_grad(&self, x: &[f32], out: &mut [f32]) {
        crate::linalg::vecops::sub(x, &self.center, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::grad_check;

    #[test]
    fn gradient_matches_finite_difference() {
        let q = Quadratic::new(vec![1.0, -2.0, 0.5], 0.0);
        grad_check(&q, &[0.3, 0.7, -1.1], 1e-3);
    }

    #[test]
    fn optimum_is_mean_of_centers() {
        let fam = vec![
            Quadratic::new(vec![0.0, 2.0], 0.0),
            Quadratic::new(vec![4.0, 0.0], 0.0),
        ];
        assert_eq!(Quadratic::optimum(&fam), vec![2.0, 1.0]);
    }

    #[test]
    fn loss_zero_at_center() {
        let q = Quadratic::new(vec![1.0, 2.0], 0.0);
        assert_eq!(q.full_loss(&[1.0, 2.0]), 0.0);
        assert!(q.full_loss(&[0.0, 0.0]) > 0.0);
    }

    #[test]
    fn stoch_grad_unbiased() {
        let mut q = Quadratic::new(vec![0.0; 4], 0.5);
        let x = [1.0f32, -1.0, 2.0, 0.0];
        let mut acc = vec![0.0f64; 4];
        let trials = 20_000;
        let mut g = vec![0.0f32; 4];
        let mut rng = Pcg64::seed_from_u64(3);
        for _ in 0..trials {
            q.stoch_grad(&x, &mut g, &mut rng);
            for (a, v) in acc.iter_mut().zip(&g) {
                *a += *v as f64;
            }
        }
        for (xi, a) in x.iter().zip(&acc) {
            assert!((a / trials as f64 - *xi as f64).abs() < 0.02);
        }
    }

    #[test]
    fn family_spread_controls_zeta() {
        let tight = Quadratic::family(8, 32, 0.1, 0.0, 1);
        let wide = Quadratic::family(8, 32, 10.0, 0.0, 1);
        let spread = |fam: &[Quadratic]| -> f64 {
            let opt = Quadratic::optimum(fam);
            fam.iter()
                .map(|q| crate::linalg::vecops::dist2_sq(&q.center, &opt))
                .sum::<f64>()
                / fam.len() as f64
        };
        assert!(spread(&wide) > 100.0 * spread(&tight));
    }
}
