//! `decomp` — the leader CLI.
//!
//! Subcommands:
//!   train         run a training job (--backend threads|sim)
//!   simulate      run the deterministic single-process reference simulator
//!   serve         long-running NDJSON job loop (stdin/stdout, --tcp ADDR)
//!   obs           replay a run with the instrumentation plane on and print
//!                 the per-phase time breakdown (--trace-out trace.json for
//!                 a Perfetto export, --validate FILE to check one)
//!   list          print the spec registry (algorithms/capabilities,
//!                 codecs/wire formulas, topologies) + self-check
//!   spectra       print mixing-matrix spectral stats for a topology
//!   fig1..fig4    regenerate a paper figure's table(s)
//!   efsweep       error-feedback family under the bandwidth×latency grid
//!   adaptsweep    adaptive per-link controller vs the static family over
//!                 the same grid (time-to-target-loss)
//!   lowranksweep  PowerGossip rank×(bandwidth,latency) grid at n=64
//!   scenariosweep fault-injection grid: churn × drops × non-IID shards
//!   ablations     run the theory-driven ablation sweeps
//!   netmodel      print the per-iteration comm-time landscape
//!   bench-summary collect the BENCH_*.json perf metrics
//!   bench-compare gate a BENCH_pr.json against a baseline
//!
//! Examples:
//!   decomp train --algo dcd --compressor q8 --nodes 8 --iters 500
//!   decomp train --algo choco --compressor sign --eta 0.4 --nodes 8
//!   decomp train --backend sim --nodes 64 --bandwidth-mbps 5 --latency-ms 5
//!   decomp train --config experiments.json --gamma 0.05
//!   decomp spectra --topology hypercube --nodes 16
//!   decomp fig3
//!   decomp bench-summary --quick --out BENCH_pr.json
//!   decomp bench-compare BENCH_baseline.json BENCH_pr.json

use decomp::algorithms::{self, RunOpts, TrainTrace};
use decomp::bench_harness::summary;
use decomp::config::{apply_cli_overrides, load_config};
use decomp::coordinator::{Backend, ObsSettings, TrainConfig};
use decomp::experiments::{
    ablations, adapt_sweep, ef_sweep, fig1, fig2, fig3, fig4, lowrank_sweep, scenario_sweep,
};
use decomp::metrics::{fmt_bytes, fmt_secs, Sink, SinkFormat, Table};
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::SimOpts;
use decomp::serve::{self, ServeOpts};
use decomp::spec;
use decomp::util::cli::Args;
use std::fs::File;
use std::io::{BufWriter, Write};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let quick = args.bool("quick", false);
    // Sweep parallelism: --sweep-threads N overrides DECOMP_SWEEP_THREADS
    // for this process (the experiment drivers read the env through
    // experiments::runner::sweep_threads).
    if let Some(threads) = args.opt_str("sweep-threads") {
        anyhow::ensure!(
            threads.parse::<usize>().map(|t| t >= 1).unwrap_or(false),
            "--sweep-threads expects a positive integer, got '{threads}'"
        );
        std::env::set_var("DECOMP_SWEEP_THREADS", threads);
    }
    // Intra-run event-loop sharding on the sim backend: --sim-shards N
    // overrides DECOMP_SIM_SHARDS. Bit-identical at any shard count
    // (deterministic merge); 1 = the serial zero-alloc loop.
    if let Some(shards) = args.opt_str("sim-shards") {
        anyhow::ensure!(
            shards.parse::<usize>().map(|t| t >= 1).unwrap_or(false),
            "--sim-shards expects a positive integer, got '{shards}'"
        );
        std::env::set_var("DECOMP_SIM_SHARDS", shards);
    }
    match cmd {
        "train" => train(&args, true),
        "simulate" => train(&args, false),
        "serve" => serve_cmd(&args),
        "obs" => obs_cmd(&args),
        "list" => list(&args),
        "spectra" => spectra(&args),
        "fig1" => emit_tables(&args, fig1::run(quick)),
        "fig2" => emit_tables(&args, fig2::run(quick)),
        "fig3" => emit_tables(&args, fig3::run(quick)),
        "fig4" => emit_tables(&args, fig4::run(quick)),
        "efsweep" => emit_tables(&args, ef_sweep::run(quick)),
        "adaptsweep" => emit_tables(&args, adapt_sweep::run(quick)),
        "lowranksweep" => emit_tables(&args, lowrank_sweep::run(quick)),
        "scenariosweep" => emit_tables(&args, scenario_sweep::run(quick)),
        "ablations" => emit_tables(&args, ablations::run(quick)),
        "netmodel" => emit_tables(&args, fig3::run(false)),
        "bench-summary" => bench_summary(&args, quick),
        "bench-compare" => bench_compare(&args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "decomp — Communication Compression for Decentralized Training (NeurIPS'18)

USAGE: decomp <command> [--flags]

COMMANDS
  train       decentralized training on a chosen execution backend
                --backend threads|sim   (threads: one OS thread per node,
                  real message passing; sim: discrete-event engine with a
                  virtual clock — scales to n >= 64 and reports modeled time)
                --algo dpsgd|dcd|ecd|naive|allreduce|choco|deepsqueeze
                --compressor fp32|q8|q4|...|sparse_p25|topk_10|sign|lowrank_rN
                --eta F  (consensus step size for choco/deepsqueeze)
                --nodes N --topology ring|full|chain|star|hypercube|
                  torus_RxC|random_pP_sS
                --gamma F --iters N --model quadratic|linear|logistic|mlp
                --bandwidth-mbps F --latency-ms F  (sim backend network condition)
                --scenario KEY  (sim backend fault injection: 'static' or a
                  '+'-joined schedule, e.g. churn_p10_l150_j300+drop_p1+
                  dirichlet_a30+bw_h50_e100+timeout_20)
                --staleness sync|quorum_q<pct>_s<rounds>  (sim backend
                  bounded-staleness execution: proceed past the gossip
                  barrier once <pct>% of neighbor frames arrived, stragglers
                  folded late, none older than <rounds> rounds; admitted for
                  staleness-safe algorithms only — choco, deepsqueeze)
                --obs off|counters|trace  (instrumentation plane; 'counters'
                  prints the per-phase time breakdown + counter/histogram
                  tables after the run; threads backend prints merged
                  per-worker counters)
                --trace-out FILE  (sim backend: stream a Perfetto
                  trace_event export while the run executes; implies
                  --obs trace)
                --config file.json (CLI flags override file values)
              note: biased compressors (topk_*, sign, lowrank_rN) are rejected
              for dcd/ecd/qallreduce — only error-feedback algorithms admit
              them; the stateful lowrank_rN family (warm-started per-link
              PowerGossip state) is admitted by choco only
  simulate    same options, deterministic single-process reference simulator
  serve       accept ExperimentSpec-shaped jobs as NDJSON lines on stdin and
              stream {accepted,progress,result,error,cancelled,done} frames
              on stdout, one JSON object per line; malformed lines get
              structured error frames, the loop never exits on bad input.
              {\"cancel\":\"id\"} lines cancel a queued or running job: the
              current cell finishes, unstarted cells are skipped, and the
              job ends with a terminal cancelled frame. --tcp HOST:PORT
              listens on a socket instead (one connection at a time). Job
              line: {\"id\":...,\"algos\":[...],\"compressors\":[...],
              \"nodes\":N,\"iters\":N,\"bandwidth_mbps\":F,\"latency_ms\":F,
              \"trace\":true,...} — every TrainConfig field by name; the
              whole algo×compressor grid is admitted through the spec layer
              before any cell runs; \"obs\":true adds counter snapshots to
              progress frames and the time breakdown to result frames
  obs         replay a run on the event engine with the instrumentation
              plane on and print where the virtual time went: per-phase
              compute/serialize/transfer/idle split for the critical node,
              plus counter and histogram tables (same --format/--out sink
              as the experiment subcommands). --trace-out trace.json also
              streams a Perfetto/Chrome trace_event export (one track per
              node, one per link; open in ui.perfetto.dev);
              --validate FILE structurally checks an existing export.
              Byte-identical across repeats and --sim-shards counts
  list        print the spec registry — every algorithm with its capability
              flags (needs_unbiased, link_state, uses_eta), every compressor
              family with its exact wire_bytes formula, every topology — then
              self-check that each entry constructs and steps on the sim
              backend at n=4
  spectra     mixing-matrix spectral stats: --topology T --nodes N
  fig1..fig4  regenerate the paper figure tables (--quick for small runs)
  efsweep     DCD/ECD/CHOCO/DeepSqueeze under the bandwidth×latency grid
              at n=64 on the event engine (--quick for small runs)
  adaptsweep  the adaptive per-link controller (choco+adapt_b2_8) against
              every static member of the efsweep family over the same
              bandwidth×latency grid: virtual time to a shared target loss
              per cell (--quick for small runs)
  lowranksweep  PowerGossip (choco+lowrank_rN) rank×condition grid at n=64,
              dim 10000 (100×100 fold) — the extreme-compression regime
  scenariosweep fault-injection grid at n=64: {static, drops, churn,
              churn+drops, non-IID, all combined} × {dpsgd, choco_topk,
              choco_sign, deepsqueeze_q4, dcd_q8, ecd_q8} — shows the
              error-feedback family riding out faults the replica family
              cannot (--quick for small runs)
  ablations   compressor/topology/heterogeneity sweeps
  netmodel    per-iteration communication-time landscape
  bench-summary  collect perf metrics: [--quick] [--out BENCH_pr.json]
  bench-compare  <baseline.json> <candidate.json> [--tolerance 0.25];
                 exits non-zero when a metric regresses past the tolerance

Every table-emitting subcommand (spectra, list, fig1..fig4, efsweep,
lowranksweep, scenariosweep, ablations, netmodel) honors
--format text|csv|json|ndjson and --out FILE; with --out and no
--format, the file extension picks the encoding. json/ndjson stream
through the zero-allocation writer — no in-memory JSON tree.

Sweep grids (fig3, efsweep, ablations) run cells in parallel on the
deterministic sweep runner; control the thread count with
--sweep-threads N (or DECOMP_SWEEP_THREADS; 1 = serial). Results are
bit-identical at any thread count.

The sim backend's event loop additionally shards *within* a run over
node ranges: --sim-shards N (or DECOMP_SIM_SHARDS; 1 = serial
zero-alloc loop). The merge is deterministic, so trajectories and
virtual times are bit-identical at any shard count. Delivery slots are
edge-keyed (O(edges), not O(n²)) — a ring at --nodes 16384 runs on a
laptop.

Set DECOMP_BACKEND=sim|threads|reference to re-route the figure
experiments (fig1..fig4, ablations) through an execution backend.";

fn load_train_config(args: &Args) -> anyhow::Result<TrainConfig> {
    let mut cfg = match args.opt_str("config") {
        Some(path) => load_config(std::path::Path::new(path))?,
        None => TrainConfig::default(),
    };
    apply_cli_overrides(&mut cfg, args);
    Ok(cfg)
}

fn train(args: &Args, threaded: bool) -> anyhow::Result<()> {
    let cfg = load_train_config(args)?;
    let backend = if threaded {
        Some(cfg.parse_backend()?)
    } else {
        None
    };
    // One construction path: TrainConfig → typed ExperimentSpec →
    // validated Session; every backend below runs from it.
    let session = cfg.experiment_spec()?.session()?;
    let algo_cfg = session.algo_config();
    let (models, x0) = cfg.build_models()?;
    let (eval_models, _) = cfg.build_models()?;
    println!(
        "{} {} | n={} topo={} comp={} gamma={} iters={} model={} dim={}",
        match backend {
            Some(Backend::Threads) => "train(threads)",
            Some(Backend::Sim) => "train(sim)",
            None => "simulate",
        },
        cfg.algo,
        cfg.n_nodes,
        cfg.topology,
        cfg.compressor,
        cfg.gamma,
        cfg.iters,
        cfg.model,
        cfg.dim
    );
    match algo_cfg.mixing.try_stats() {
        Some(s) => println!(
            "mixing: rho={:.4} mu={:.4} gap={:.4} dcd_alpha_bound={:.4}",
            s.rho,
            s.mu,
            s.gap,
            algo_cfg.mixing.dcd_alpha_bound()
        ),
        // Past the dense-oracle cap the mixing matrix is CSR-only; the
        // O(n³) Jacobi spectrum is deliberately skipped at sweep scale.
        None => println!(
            "mixing: sparse CSR rows only (spectral stats skipped past n={})",
            decomp::topology::MixingMatrix::DENSE_ORACLE_MAX
        ),
    }

    if backend == Some(Backend::Sim) {
        // Discrete-event backend: virtual clock, per-link costs, honest
        // frame accounting. Network condition from --bandwidth-mbps /
        // --latency-ms (defaults: the paper's worst case).
        let net = NetworkModel::new(
            args.f64("bandwidth-mbps", 5.0) * 1e6,
            args.f64("latency-ms", 5.0) * 1e-3,
        );
        let opts = RunOpts {
            iters: cfg.iters,
            gamma: cfg.gamma,
            eval_every: cfg.eval_every,
            ..Default::default()
        };
        let sim = SimOpts {
            cost: CostModel::Uniform(net),
            staleness: None,
            compute_per_iter_s: args.f64("compute-ms", 0.0) * 1e-3,
            scenario: None,
        };
        let obs_spec = resolve_obs_spec(&cfg, args)?;
        let t0 = std::time::Instant::now();
        let trace = if obs_spec.counters_on() {
            let traced = session.run_sim_traced(
                models,
                &eval_models,
                &x0,
                &opts,
                sim,
                obs_settings(obs_spec, args)?,
            )?;
            if let Some(report) = &traced.run.obs {
                for table in report.tables() {
                    table.print();
                }
            }
            if let Some(path) = args.opt_str("trace-out") {
                println!("perfetto trace written to {path}");
            }
            traced.trace
        } else {
            session.run_sim_trace(models, &eval_models, &x0, &opts, sim)?
        };
        let wall = t0.elapsed().as_secs_f64();
        let mut t = Table::new(
            "sim-backend run (virtual time)",
            &["iter", "f_mean", "consensus", "bytes", "virtual_t"],
        );
        for p in &trace.points {
            t.row(vec![
                p.iter.to_string(),
                format!("{:.5}", p.global_loss),
                format!("{:.3e}", p.consensus),
                fmt_bytes(p.bytes_sent as f64),
                fmt_secs(p.sim_time_s),
            ]);
        }
        t.print();
        let last = trace.points.last().unwrap();
        println!(
            "final f(x̄) = {:.5} | modeled time = {} for {} iters | host wall = {wall:.2}s",
            last.global_loss,
            fmt_secs(last.sim_time_s),
            cfg.iters
        );
        write_trace(args, &trace, &t)?;
        return Ok(());
    }

    if threaded {
        let obs_on = cfg.parse_obs()?.counters_on();
        let t0 = std::time::Instant::now();
        let (run, registry) = if obs_on {
            let (run, reg) = session.run_threaded_obs(models, &x0, cfg.gamma, cfg.iters)?;
            (run, Some(reg))
        } else {
            (session.run_threaded(models, &x0, cfg.gamma, cfg.iters)?, None)
        };
        let wall = t0.elapsed().as_secs_f64();
        let mean = run.mean_params();
        let final_loss: f64 = eval_models.iter().map(|m| m.full_loss(&mean)).sum::<f64>()
            / eval_models.len() as f64;
        let mut t = Table::new("threaded run", &["iter", "mean_minibatch_loss"]);
        let losses = run.mean_losses();
        for (i, l) in decomp::util::stats::downsample(&losses, 12) {
            t.row(vec![i.to_string(), format!("{l:.5}")]);
        }
        t.print();
        if let Some(reg) = &registry {
            reg.counters_table(&format!("counters ({})", cfg.algo)).print();
            reg.hists_table(&format!("histograms ({})", cfg.algo)).print();
        }
        println!(
            "final f(x̄) = {final_loss:.5} | bytes on wire = {} | wall = {wall:.2}s",
            fmt_bytes(run.total_bytes() as f64)
        );
    } else {
        let mut models = models;
        let mut algo = session.reference(&x0, cfg.n_nodes);
        let opts = RunOpts {
            iters: cfg.iters,
            gamma: cfg.gamma,
            eval_every: cfg.eval_every,
            ..Default::default()
        };
        let trace = algorithms::run_training(algo.as_mut(), &mut models, &opts);
        let mut t = Table::new("simulated run", &["iter", "f_mean", "consensus", "bytes"]);
        for p in &trace.points {
            t.row(vec![
                p.iter.to_string(),
                format!("{:.5}", p.global_loss),
                format!("{:.3e}", p.consensus),
                fmt_bytes(p.bytes_sent as f64),
            ]);
        }
        t.print();
        // --out file.json / --out file.csv: persist the trace.
        write_trace(args, &trace, &t)?;
    }
    Ok(())
}

/// Persist a run's trace when `--out` is given: `.csv` writes the
/// printed table, anything else streams the trace as pretty JSON
/// through [`JsonWriter`](decomp::util::json::JsonWriter) — point by
/// point, no intermediate tree, O(1) memory in the trace length.
fn write_trace(args: &Args, trace: &TrainTrace, t: &Table) -> anyhow::Result<()> {
    if let Some(path) = args.opt_str("out") {
        if path.ends_with(".csv") {
            std::fs::write(path, t.to_csv())?;
        } else {
            let mut f = BufWriter::new(File::create(path)?);
            trace.write_json(&mut f, true)?;
            f.flush()?;
        }
        println!("trace written to {path}");
    }
    Ok(())
}

/// The sim run's observation level: the `--obs`/config knob,
/// force-upgraded to `trace` when `--trace-out` names a sink.
fn resolve_obs_spec(cfg: &TrainConfig, args: &Args) -> anyhow::Result<spec::ObsSpec> {
    let parsed = cfg.parse_obs()?;
    Ok(if args.opt_str("trace-out").is_some() {
        spec::ObsSpec::Trace
    } else {
        parsed
    })
}

/// Build the [`ObsSettings`] for a sim run, opening the `--trace-out`
/// file behind a buffered writer when the level asks for the Perfetto
/// stream.
fn obs_settings(level: spec::ObsSpec, args: &Args) -> anyhow::Result<ObsSettings> {
    let trace_out: Option<Box<dyn Write + Send>> = match args.opt_str("trace-out") {
        Some(path) if level.trace_on() => Some(Box::new(BufWriter::new(File::create(path)?))),
        _ => None,
    };
    Ok(ObsSettings {
        spec: level,
        trace_out,
    })
}

/// `decomp obs`: replay a run with the instrumentation plane on and
/// print where the virtual time went — the per-phase breakdown plus the
/// counter and histogram tables, through the shared sink
/// (`--format text|csv|json|ndjson`, `--out FILE`). `--trace-out FILE`
/// additionally streams the Perfetto `trace_event` export;
/// `--validate FILE` instead structurally validates an existing export
/// and exits. All observed quantities derive from the virtual clock, so
/// the printed report is byte-identical across repeats and shard
/// counts.
fn obs_cmd(args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.opt_str("validate") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace '{path}': {e}"))?;
        let stats =
            decomp::obs::trace::validate(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!(
            "{path}: valid perfetto trace — {} event(s), {} span(s)",
            stats.events, stats.spans
        );
        return Ok(());
    }
    let mut cfg = load_train_config(args)?;
    cfg.backend = "sim".into();
    let level = if args.opt_str("trace-out").is_some() {
        spec::ObsSpec::Trace
    } else {
        spec::ObsSpec::Counters
    };
    let session = cfg.experiment_spec()?.session()?;
    let (models, x0) = cfg.build_models()?;
    let (eval_models, _) = cfg.build_models()?;
    let net = NetworkModel::new(
        args.f64("bandwidth-mbps", 5.0) * 1e6,
        args.f64("latency-ms", 5.0) * 1e-3,
    );
    let opts = RunOpts {
        iters: cfg.iters,
        gamma: cfg.gamma,
        eval_every: cfg.eval_every,
        ..Default::default()
    };
    let sim = SimOpts {
        cost: CostModel::Uniform(net),
        staleness: None,
        compute_per_iter_s: args.f64("compute-ms", 0.0) * 1e-3,
        scenario: None,
    };
    let settings = obs_settings(level, args)?;
    let traced = session.run_sim_traced(models, &eval_models, &x0, &opts, sim, settings)?;
    let report = traced
        .run
        .obs
        .as_ref()
        .expect("obs is always on for `decomp obs`");
    emit_tables(args, report.tables())?;
    if let Some(path) = args.opt_str("trace-out") {
        eprintln!("perfetto trace written to {path}");
    }
    Ok(())
}

fn spectra(args: &Args) -> anyhow::Result<()> {
    let cfg = load_train_config(args)?;
    let mixing = cfg.build_mixing()?;
    let stats = mixing.try_stats().ok_or_else(|| {
        anyhow::anyhow!(
            "spectra needs the dense oracle, which is only computed for n <= {} \
             (Jacobi is O(n^3)); got n = {}",
            decomp::topology::MixingMatrix::DENSE_ORACLE_MAX,
            cfg.n_nodes
        )
    })?;
    let mut t = Table::new(
        &format!("spectra: {} n={}", cfg.topology, cfg.n_nodes),
        &["stat", "value"],
    );
    t.row(vec!["lambda2".into(), format!("{:.6}", stats.lambda2)]);
    t.row(vec!["lambda_n".into(), format!("{:.6}", stats.lambda_n)]);
    t.row(vec!["rho".into(), format!("{:.6}", stats.rho)]);
    t.row(vec!["mu".into(), format!("{:.6}", stats.mu)]);
    t.row(vec!["spectral_gap".into(), format!("{:.6}", stats.gap)]);
    t.row(vec![
        "dcd_alpha_bound".into(),
        format!("{:.6}", mixing.dcd_alpha_bound()),
    ]);
    emit_tables(args, vec![t])
}

/// Build the one output sink every table-emitting subcommand shares:
/// `--format text|csv|json|ndjson` (or inferred from the `--out` file
/// extension) chooses the encoding, `--out FILE` the destination.
fn make_sink(args: &Args) -> anyhow::Result<Sink> {
    Sink::from_args(args.opt_str("format"), args.opt_str("out")).map_err(|e| anyhow::anyhow!(e))
}

fn emit_tables(args: &Args, tables: Vec<Table>) -> anyhow::Result<()> {
    make_sink(args)?.emit(&tables)?;
    if let Some(path) = args.opt_str("out") {
        eprintln!("written to {path}");
    }
    Ok(())
}

/// `decomp serve`: long-running NDJSON job loop — stdin/stdout by
/// default, a TCP listener with `--tcp ADDR`. Sweep parallelism inside
/// each job grid follows `--sweep-threads` / `DECOMP_SWEEP_THREADS`.
fn serve_cmd(args: &Args) -> anyhow::Result<()> {
    let opts = ServeOpts::default();
    if let Some(addr) = args.opt_str("tcp") {
        return serve::serve_tcp(addr, &opts);
    }
    // BufReader over Stdin (not StdinLock): the serve loop pumps input
    // through a reader thread, so the reader must be Send.
    let input = std::io::BufReader::new(std::io::stdin());
    let stdout = std::io::stdout();
    let stats = serve::serve(input, stdout.lock(), &opts)?;
    eprintln!(
        "decomp serve: input closed — {} job(s) ok, {} rejected, {} cancelled, {} cell(s) run",
        stats.jobs_ok, stats.jobs_rejected, stats.jobs_cancelled, stats.cells_run
    );
    Ok(())
}

/// `decomp list`: print the spec registry (every algorithm with its
/// capability flags, every compressor family with its wire_bytes
/// formula, every topology family), then self-check that every registry
/// entry actually constructs and steps on the sim backend at n=4 — the
/// CI smoke that catches registry/implementation drift.
fn list(args: &Args) -> anyhow::Result<()> {
    let sink = make_sink(args)?;
    sink.emit(&spec::registry::list_tables())?;
    let cells = spec::registry::self_check(4)?;
    let msg = format!(
        "registry self-check OK: {cells} cells constructed and stepped on the sim backend at n=4"
    );
    // Keep machine-readable stdout (json/ndjson/csv) free of the status
    // line; the text default keeps its historical stdout shape.
    if sink.format() == SinkFormat::Text && args.opt_str("out").is_none() {
        println!("{msg}");
    } else {
        eprintln!("{msg}");
    }
    Ok(())
}

/// Collect the perf metrics and optionally persist them as BENCH JSON.
fn bench_summary(args: &Args, quick: bool) -> anyhow::Result<()> {
    let report = summary::collect(quick);
    report.to_table().print();
    if let Some(path) = args.opt_str("out") {
        let mut f = BufWriter::new(File::create(path)?);
        report.write_json(&mut f)?;
        f.flush()?;
        println!("bench summary written to {path}");
    }
    Ok(())
}

fn load_bench(path: &str) -> anyhow::Result<summary::BenchReport> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read bench file '{path}': {e}"))?;
    summary::BenchReport::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e:#}"))
}

/// Gate a candidate BENCH json against a baseline; non-zero exit on
/// regression (the CI bench-smoke contract).
fn bench_compare(args: &Args) -> anyhow::Result<()> {
    anyhow::ensure!(
        args.positional.len() == 3,
        "usage: decomp bench-compare <baseline.json> <candidate.json> [--tolerance 0.25]"
    );
    let (base_path, cand_path) = (&args.positional[1], &args.positional[2]);
    let tolerance = args.f64("tolerance", 0.25);
    let base = load_bench(base_path)?;
    let cand = load_bench(cand_path)?;
    let out = summary::compare(&base, &cand, tolerance);
    if out.regressions.is_empty() {
        println!(
            "bench-compare OK: {} metric(s) within {:.0}% of {base_path}",
            out.compared,
            tolerance * 100.0
        );
        return Ok(());
    }
    let mut t = Table::new(
        &format!("bench-compare: regressions past {:.0}%", tolerance * 100.0),
        &["metric", "baseline", "candidate", "worse_by"],
    );
    for r in &out.regressions {
        t.row(vec![
            r.metric.clone(),
            format!("{:.6}", r.baseline),
            format!("{:.6}", r.candidate),
            format!("{:.1}%", r.worse_by * 100.0),
        ]);
    }
    t.print();
    anyhow::bail!(
        "{} of {} compared metric(s) regressed more than {:.0}% vs {base_path}",
        out.regressions.len(),
        out.compared,
        tolerance * 100.0
    );
}
