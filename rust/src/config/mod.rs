//! Config-file loading: `TrainConfig` from a JSON file with CLI
//! overrides. (The offline environment has no serde, so this maps fields
//! explicitly through [`crate::util::json::Json`].)
//!
//! The spec-shaped keys (`algo`, `compressor`, `topology`) parse through
//! the typed spec layer at load time — a typo'd value fails *here* with
//! the registered-name list, not deep inside a run — and are stored in
//! canonical form (`chocosgd` → `choco`, `full` → `fully_connected`).

use crate::coordinator::TrainConfig;
use crate::spec::{AlgoSpec, CompressorSpec, ObsSpec, ScenarioSpec, StalenessSpec, TopologySpec};
use crate::util::cli::Args;
use crate::util::json::Json;
use std::path::Path;

/// Load a TrainConfig from a JSON file. Unknown keys are rejected so
/// typos fail loudly.
pub fn load_config(path: &Path) -> anyhow::Result<TrainConfig> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("config parse: {e}"))?;
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("config must be a JSON object"))?;
    let mut cfg = TrainConfig::default();
    for (k, v) in obj {
        match k.as_str() {
            "algo" => cfg.algo = req_spec::<AlgoSpec>(v, k)?,
            "n_nodes" => cfg.n_nodes = req_usize(v, k)?,
            "topology" => cfg.topology = req_spec::<TopologySpec>(v, k)?,
            "compressor" => cfg.compressor = req_spec::<CompressorSpec>(v, k)?,
            "gamma" => cfg.gamma = req_f64(v, k)? as f32,
            "iters" => cfg.iters = req_usize(v, k)?,
            "eval_every" => cfg.eval_every = req_usize(v, k)?,
            "seed" => cfg.seed = req_usize(v, k)? as u64,
            "model" => cfg.model = req_str(v, k)?,
            "dim" => cfg.dim = req_usize(v, k)?,
            "rows_per_node" => cfg.rows_per_node = req_usize(v, k)?,
            "heterogeneity" => cfg.heterogeneity = req_f64(v, k)? as f32,
            "batch" => cfg.batch = req_usize(v, k)?,
            "backend" => cfg.backend = req_str(v, k)?,
            "eta" => cfg.eta = req_f64(v, k)? as f32,
            "scenario" => cfg.scenario = req_spec::<ScenarioSpec>(v, k)?,
            "staleness" => cfg.staleness = req_spec::<StalenessSpec>(v, k)?,
            "obs" => cfg.obs = req_spec::<ObsSpec>(v, k)?,
            other => anyhow::bail!("unknown config key '{other}'"),
        }
    }
    Ok(cfg)
}

/// Apply `--key value` CLI overrides on top of a config.
pub fn apply_cli_overrides(cfg: &mut TrainConfig, args: &Args) {
    if let Some(v) = args.opt_str("algo") {
        cfg.algo = v.to_string();
    }
    if let Some(v) = args.opt_str("topology") {
        cfg.topology = v.to_string();
    }
    if let Some(v) = args.opt_str("compressor") {
        cfg.compressor = v.to_string();
    }
    if let Some(v) = args.opt_str("model") {
        cfg.model = v.to_string();
    }
    if let Some(v) = args.opt_str("backend") {
        cfg.backend = v.to_string();
    }
    cfg.n_nodes = args.usize("nodes", cfg.n_nodes);
    cfg.gamma = args.f64("gamma", cfg.gamma as f64) as f32;
    cfg.iters = args.usize("iters", cfg.iters);
    cfg.eval_every = args.usize("eval-every", cfg.eval_every);
    cfg.seed = args.u64("seed", cfg.seed);
    cfg.dim = args.usize("dim", cfg.dim);
    cfg.rows_per_node = args.usize("rows", cfg.rows_per_node);
    cfg.heterogeneity = args.f64("heterogeneity", cfg.heterogeneity as f64) as f32;
    cfg.batch = args.usize("batch", cfg.batch);
    cfg.eta = args.f64("eta", cfg.eta as f64) as f32;
    if let Some(v) = args.opt_str("scenario") {
        cfg.scenario = v.to_string();
    }
    if let Some(v) = args.opt_str("staleness") {
        cfg.staleness = v.to_string();
    }
    if let Some(v) = args.opt_str("obs") {
        cfg.obs = v.to_string();
    }
}

fn req_str(v: &Json, key: &str) -> anyhow::Result<String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("config key '{key}' must be a string"))
}

/// Parse a string key through a typed spec and store its canonical
/// `Display` form; the error names the key and lists the registered
/// names.
fn req_spec<T>(v: &Json, key: &str) -> anyhow::Result<String>
where
    T: std::str::FromStr<Err = crate::spec::SpecParseError> + std::fmt::Display,
{
    let s = req_str(v, key)?;
    let spec: T = s
        .parse()
        .map_err(|e| anyhow::anyhow!("config key '{key}': {e}"))?;
    Ok(spec.to_string())
}

fn req_usize(v: &Json, key: &str) -> anyhow::Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow::anyhow!("config key '{key}' must be a non-negative integer"))
}

fn req_f64(v: &Json, key: &str) -> anyhow::Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow::anyhow!("config key '{key}' must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, contents: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("decomp_cfg_{}_{name}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(contents.as_bytes()).unwrap();
        p
    }

    #[test]
    fn loads_full_config() {
        let p = write_tmp(
            "full.json",
            r#"{"algo":"ecd","n_nodes":16,"topology":"hypercube","compressor":"q4",
                "gamma":0.02,"iters":100,"eval_every":10,"seed":7,"model":"mlp",
                "dim":32,"rows_per_node":64,"heterogeneity":1.5,"batch":4}"#,
        );
        let cfg = load_config(&p).unwrap();
        assert_eq!(cfg.algo, "ecd");
        assert_eq!(cfg.n_nodes, 16);
        assert_eq!(cfg.topology, "hypercube");
        assert_eq!(cfg.compressor, "q4");
        assert!((cfg.gamma - 0.02).abs() < 1e-7);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.model, "mlp");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let p = write_tmp("partial.json", r#"{"algo":"dpsgd"}"#);
        let cfg = load_config(&p).unwrap();
        assert_eq!(cfg.algo, "dpsgd");
        assert_eq!(cfg.n_nodes, TrainConfig::default().n_nodes);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn unknown_key_rejected() {
        let p = write_tmp("bad.json", r#"{"algoz":"dpsgd"}"#);
        assert!(load_config(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn wrong_type_rejected() {
        let p = write_tmp("type.json", r#"{"n_nodes":"eight"}"#);
        assert!(load_config(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn spec_keys_validate_and_canonicalize_at_load() {
        // A typo'd spec value fails at load time with the registered list.
        let p = write_tmp("badalgo.json", r#"{"algo":"sgd9000"}"#);
        let err = load_config(&p).unwrap_err().to_string();
        assert!(err.contains("registered") && err.contains("dpsgd"), "{err}");
        std::fs::remove_file(p).ok();
        let p = write_tmp("badcomp.json", r#"{"compressor":"zstd"}"#);
        assert!(load_config(&p).is_err());
        std::fs::remove_file(p).ok();
        // Aliases canonicalize; parameterized topologies parse.
        let p = write_tmp(
            "canon.json",
            r#"{"algo":"chocosgd","compressor":"identity","topology":"torus_3x4","eta":0.4}"#,
        );
        let cfg = load_config(&p).unwrap();
        assert_eq!(cfg.algo, "choco");
        assert_eq!(cfg.compressor, "fp32");
        assert_eq!(cfg.topology, "torus_3x4");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn cli_overrides_win() {
        let mut cfg = TrainConfig::default();
        let args = Args::parse_from(
            "--algo ecd --nodes 12 --gamma 0.5 --backend sim"
                .split_whitespace()
                .map(|s| s.to_string()),
        );
        apply_cli_overrides(&mut cfg, &args);
        assert_eq!(cfg.algo, "ecd");
        assert_eq!(cfg.n_nodes, 12);
        assert!((cfg.gamma - 0.5).abs() < 1e-7);
        assert_eq!(cfg.backend, "sim");
    }

    #[test]
    fn eta_key_loads_and_overrides() {
        let p = write_tmp("eta.json", r#"{"algo":"choco","compressor":"sign","eta":0.3}"#);
        let mut cfg = load_config(&p).unwrap();
        assert!((cfg.eta - 0.3).abs() < 1e-7);
        let args = Args::parse_from(["--eta", "0.7"].iter().map(|s| s.to_string()));
        apply_cli_overrides(&mut cfg, &args);
        assert!((cfg.eta - 0.7).abs() < 1e-7);
        std::fs::remove_file(p).ok();
        assert_eq!(TrainConfig::default().eta, 1.0);
    }

    #[test]
    fn scenario_key_loads_canonicalizes_and_overrides() {
        // Parses through the typed spec at load time and stores the
        // canonical Display form (part order is normalized).
        let p = write_tmp("scen.json", r#"{"scenario":"drop_p5+churn_p10_l150_j300"}"#);
        let mut cfg = load_config(&p).unwrap();
        assert_eq!(cfg.scenario, "churn_p10_l150_j300+drop_p5");
        std::fs::remove_file(p).ok();
        // A malformed schedule fails at load, naming the key.
        let p = write_tmp("scenbad.json", r#"{"scenario":"churn_p0_l1_j2"}"#);
        let err = load_config(&p).unwrap_err().to_string();
        assert!(err.contains("scenario"), "{err}");
        std::fs::remove_file(p).ok();
        // CLI wins over file.
        let args = Args::parse_from(["--scenario", "drop_p1"].iter().map(|s| s.to_string()));
        apply_cli_overrides(&mut cfg, &args);
        assert_eq!(cfg.scenario, "drop_p1");
        assert_eq!(TrainConfig::default().scenario, "static");
    }

    #[test]
    fn staleness_key_loads_canonicalizes_and_overrides() {
        let p = write_tmp("stale.json", r#"{"staleness":"quorum_q75_s3"}"#);
        let mut cfg = load_config(&p).unwrap();
        assert_eq!(cfg.staleness, "quorum_q75_s3");
        std::fs::remove_file(p).ok();
        // Malformed disciplines fail at load, naming the key.
        let p = write_tmp("stalebad.json", r#"{"staleness":"quorum_q100_s1"}"#);
        let err = load_config(&p).unwrap_err().to_string();
        assert!(err.contains("staleness"), "{err}");
        std::fs::remove_file(p).ok();
        // CLI wins over file.
        let args =
            Args::parse_from(["--staleness", "quorum_q50_s2"].iter().map(|s| s.to_string()));
        apply_cli_overrides(&mut cfg, &args);
        assert_eq!(cfg.staleness, "quorum_q50_s2");
        assert_eq!(TrainConfig::default().staleness, "sync");
    }

    #[test]
    fn obs_key_loads_validates_and_overrides() {
        let p = write_tmp("obs.json", r#"{"obs":"counters"}"#);
        let mut cfg = load_config(&p).unwrap();
        assert_eq!(cfg.obs, "counters");
        std::fs::remove_file(p).ok();
        // An unknown level fails at load, naming the key.
        let p = write_tmp("obsbad.json", r#"{"obs":"loud"}"#);
        let err = load_config(&p).unwrap_err().to_string();
        assert!(err.contains("obs"), "{err}");
        std::fs::remove_file(p).ok();
        // CLI wins over file.
        let args = Args::parse_from(["--obs", "trace"].iter().map(|s| s.to_string()));
        apply_cli_overrides(&mut cfg, &args);
        assert_eq!(cfg.obs, "trace");
        assert_eq!(TrainConfig::default().obs, "off");
    }

    #[test]
    fn backend_key_loads_and_validates() {
        let p = write_tmp("backend.json", r#"{"backend":"sim"}"#);
        let cfg = load_config(&p).unwrap();
        assert_eq!(cfg.backend, "sim");
        cfg.parse_backend().unwrap();
        std::fs::remove_file(p).ok();
        assert_eq!(TrainConfig::default().backend, "threads");
    }
}
