//! Bench: PJRT runtime overhead — the L2/L1 step latencies as seen from
//! the rust hot path. Skips (with a notice) when artifacts are missing.
//!
//! Measures:
//!   grad_step      — fwd/bwd of the transformer (the L2 compute)
//!   dcd_step       — the fused local step (adds the Pallas gossip +
//!                    quantization kernels; the delta vs grad_step is the
//!                    interpret-mode kernel cost, NOT a TPU proxy)
//!   quantize8      — the standalone Pallas quantization artifact
//!   rust_quantize  — the native rust codec on the same vector, for
//!                    an apples-to-apples L3-vs-interpreted-L1 comparison

use decomp::bench_harness::{report, time_fn, time_throughput, BenchOpts};
use decomp::compression::{Compressor, StochasticQuantizer};
use decomp::runtime::{PjrtEngine, TokenSampler};
use decomp::util::rng::Pcg64;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP runtime_overhead: artifacts/ not built (run `make artifacts`)");
        return Ok(());
    }
    let engine = Arc::new(PjrtEngine::load(&dir)?);
    let m = engine.manifest.clone();
    println!(
        "runtime: {} params, padded {}, batch {}, seq {}",
        m.param_count, m.padded_dim, m.batch, m.seq_len
    );
    let opts = BenchOpts {
        warmup_iters: 1,
        measure_iters: if decomp::bench_harness::quick_mode() { 3 } else { 6 },
    };

    let params = m.load_init_params()?;
    let sampler = TokenSampler {
        vocab: m.vocab as i32,
        seq_len: m.seq_len,
        batch: m.batch,
        node: 0,
    };
    let mut rng = Pcg64::seed_from_u64(1);
    let tokens = sampler.sample(&mut rng);

    let grad = time_fn("pjrt/grad_step", opts, || {
        std::hint::black_box(engine.grad_step(&params, &tokens).unwrap());
    });

    let mut x = vec![0.0f32; m.padded_dim];
    x[..m.param_count].copy_from_slice(&params);
    let mut neighbors = Vec::with_capacity(2 * m.padded_dim);
    neighbors.extend_from_slice(&x);
    neighbors.extend_from_slice(&x);
    let weights = vec![1.0f32 / 3.0; m.degree + 1];
    let dcd = time_fn("pjrt/dcd_step(fused)", opts, || {
        std::hint::black_box(
            engine
                .dcd_step(&x, &neighbors, &weights, 0.1, &tokens, 7)
                .unwrap(),
        );
    });

    let mut z = vec![0.0f32; m.padded_dim];
    rng.fill_normal_f32(&mut z, 0.0, 0.1);
    let quant = time_throughput("pjrt/quantize8(pallas-interpret)", opts, m.padded_dim as f64, || {
        std::hint::black_box(engine.quantize(&z, 42).unwrap());
    });

    let q8 = StochasticQuantizer::new(8);
    let mut qrng = Pcg64::seed_from_u64(2);
    let rust_q = time_throughput("rust/quantize8(native codec)", opts, m.padded_dim as f64, || {
        std::hint::black_box(q8.compress(&z, &mut qrng));
    });

    report("PJRT step latencies", &[grad, dcd]).print();
    println!();
    report("quantization: interpreted Pallas vs native rust", &[quant, rust_q]).print();
    println!(
        "\nNote: interpret=True Pallas timings are a CPU-emulation artifact, not a\n\
         TPU estimate — see DESIGN.md §Hardware-Adaptation / EXPERIMENTS.md §Perf."
    );
    Ok(())
}
