//! Bench: ablations — compressor α sweep vs the DCD admissibility bound,
//! topology spectra, and the heterogeneity (ζ) sweep.

fn main() {
    let quick = decomp::bench_harness::quick_mode();
    for t in decomp::experiments::ablations::run(quick) {
        t.print();
        println!();
    }
}
