//! Bench: the scenario sweep — the fault-injection grid (churn × lossy
//! links × non-IID shards) over the six-member algorithm panel at n = 64
//! on the discrete-event engine.

fn main() {
    println!(
        "scenario sweep (experiment backend: sim; quick: {})\n",
        decomp::bench_harness::quick_mode()
    );
    for t in decomp::experiments::scenario_sweep::run(decomp::bench_harness::quick_mode()) {
        t.print();
        println!();
    }
}
