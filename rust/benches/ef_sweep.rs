//! Bench: the error-feedback sweep — DCD/ECD/CHOCO/DeepSqueeze under the
//! §5.2 bandwidth×latency grid at n = 64 on the discrete-event engine.

fn main() {
    println!(
        "ef sweep (experiment backend: sim; quick: {})\n",
        decomp::bench_harness::quick_mode()
    );
    for t in decomp::experiments::ef_sweep::run(decomp::bench_harness::quick_mode()) {
        t.print();
        println!();
    }
}
