//! Bench: regenerate Figure 2(a) — convergence vs iteration for
//! Allreduce / decentralized fp32 / DCD q8 / ECD q8.

fn main() {
    let quick = decomp::bench_harness::quick_mode();
    let tables = decomp::experiments::fig2::run(quick);
    // Table 0 is Fig 2(a); the runtime tables are printed by fig2_runtime.
    tables[0].print();
}
