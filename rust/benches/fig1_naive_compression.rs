//! Bench: regenerate Figure 1 (D-PSGD vs naive compression).
//! `DECOMP_BENCH_QUICK=1` shrinks the run.

fn main() {
    let quick = decomp::bench_harness::quick_mode();
    for t in decomp::experiments::fig1::run(quick) {
        t.print();
        println!();
    }
}
