//! Bench: the adaptive per-link controller (choco + adapt_b2_8) against
//! every static member of the EF family under the §5.2 bandwidth×latency
//! grid, scored on virtual time to a shared target loss.

fn main() {
    println!(
        "adapt sweep (experiment backend: sim; quick: {})\n",
        decomp::bench_harness::quick_mode()
    );
    for t in decomp::experiments::adapt_sweep::run(decomp::bench_harness::quick_mode()) {
        t.print();
        println!();
    }
}
