//! Bench: regenerate Figure 2(b,c,d) — loss vs simulated wall-clock
//! under the three tc-shaped network conditions, plus the comm-time
//! summary that drives the crossovers.

fn main() {
    let quick = decomp::bench_harness::quick_mode();
    let tables = decomp::experiments::fig2::run(quick);
    for t in &tables[1..] {
        t.print();
        println!();
    }
}
