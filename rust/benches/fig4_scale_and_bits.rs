//! Bench: regenerate Figure 4 — (a) 16 nodes at 8 bits, (b) 4-bit
//! stress, plus the quantizer-α vs DCD-bound table.

fn main() {
    let quick = decomp::bench_harness::quick_mode();
    for t in decomp::experiments::fig4::run(quick) {
        t.print();
        println!();
    }
}
