//! Bench: regenerate Figure 3 (a–d) — epoch time vs bandwidth and
//! latency for the three implementations (pure cost model; deterministic).

fn main() {
    for t in decomp::experiments::fig3::run(false) {
        t.print();
        println!();
    }
}
