//! Bench: regenerate Figure 3 — epoch time vs bandwidth and latency for
//! the three implementations (closed-form cost model), plus the measured
//! large-n ring sweep on the discrete-event engine (n up to 64).

fn main() {
    println!(
        "fig3 network sweep (experiment backend: {})\n",
        decomp::bench_harness::backend_mode()
    );
    for t in decomp::experiments::fig3::run(decomp::bench_harness::quick_mode()) {
        t.print();
        println!();
    }
}
