//! Bench: the low-rank sweep — PowerGossip (CHOCO + warm-started rank-r
//! link compression) over the rank×(bandwidth,latency) grid at n = 64 on
//! the discrete-event engine.

fn main() {
    println!(
        "lowrank sweep (experiment backend: sim; quick: {})\n",
        decomp::bench_harness::quick_mode()
    );
    for t in decomp::experiments::lowrank_sweep::run(decomp::bench_harness::quick_mode()) {
        t.print();
        println!();
    }
}
