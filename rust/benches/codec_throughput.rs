//! Bench: L3 codec hot path — compress/decompress throughput of every
//! wire format, plus the gossip weighted-sum kernel. This is the
//! §Perf measurement target for the rust layer (see EXPERIMENTS.md).

use decomp::bench_harness::{report, time_throughput, BenchOpts};
use decomp::compression::{Compressor, Identity, RandomSparsifier, StochasticQuantizer, TopK};
use decomp::linalg::vecops;
use decomp::util::rng::Pcg64;

fn main() {
    let n: usize = if decomp::bench_harness::quick_mode() {
        1 << 18
    } else {
        1 << 22 // 4M f32 = 16 MB — a ~4M-parameter model delta
    };
    let opts = BenchOpts {
        warmup_iters: 2,
        measure_iters: 8,
    };
    let mut rng = Pcg64::seed_from_u64(1);
    let mut z = vec![0.0f32; n];
    rng.fill_normal_f32(&mut z, 0.0, 1.0);
    let mut out = vec![0.0f32; n];

    let codecs: Vec<Box<dyn Compressor>> = vec![
        Box::new(Identity),
        Box::new(StochasticQuantizer::new(8)),
        Box::new(StochasticQuantizer::new(4)),
        Box::new(StochasticQuantizer::new(2)),
        Box::new(StochasticQuantizer::new(1)),
        Box::new(RandomSparsifier::new(0.25)),
        Box::new(TopK::new(0.1)),
    ];

    let mut compress_ms = Vec::new();
    let mut decompress_ms = Vec::new();
    for c in &codecs {
        let mut crng = Pcg64::seed_from_u64(2);
        compress_ms.push(time_throughput(
            &format!("compress/{}", c.name()),
            opts,
            n as f64,
            || {
                std::hint::black_box(c.compress(&z, &mut crng));
            },
        ));
        let wire = c.compress(&z, &mut Pcg64::seed_from_u64(3));
        decompress_ms.push(time_throughput(
            &format!("decompress/{}", c.name()),
            opts,
            n as f64,
            || {
                c.decompress(&wire, &mut out);
                std::hint::black_box(&out);
            },
        ));
    }
    report(&format!("codec compress throughput (n = {n} f32, elems/s)"), &compress_ms).print();
    println!();
    report(&format!("codec decompress throughput (n = {n} f32, elems/s)"), &decompress_ms).print();
    println!();

    // Gossip weighted-sum (the degree-2 ring mix) + axpy SGD step.
    let a = z.clone();
    let b = z.clone();
    let mut g = vec![0.0f32; n];
    rng.fill_normal_f32(&mut g, 0.0, 1.0);
    let weights = [1.0f32 / 3.0, 1.0 / 3.0, 1.0 / 3.0];
    let gossip = time_throughput("gossip_mix+sgd(deg2)", opts, n as f64, || {
        let cols: [&[f32]; 3] = [&z, &a, &b];
        vecops::weighted_sum(&weights, &cols, &mut out);
        vecops::axpy(-0.1, &g, &mut out);
        std::hint::black_box(&out);
    });
    report("L3 gossip hot path (elems/s)", &[gossip]).print();
}
