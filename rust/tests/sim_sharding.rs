//! Sharded event loop ⇔ serial loop bit-identity on real algorithms.
//!
//! The sim engine can shard emit/absorb across `std::thread::scope`
//! threads over contiguous node ranges (`SimEngine::with_links`); the
//! merge assigns arrival sequence numbers in shard order — which *is*
//! global node order — so trajectories, byte accounting, and every
//! virtual timestamp must be **bitwise identical** at any shard count.
//! This is the acceptance pin for that claim: a full error-feedback
//! algorithm (choco + biased top-k, the heaviest per-node state in the
//! tree) and a hub-rooted reduction, compared at 1/2/4 shards — including
//! a shard count that does not divide n.

use decomp::coordinator::TrainConfig;
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::{run_sim_on, LinkTable, SimEngine, SimOpts, SimRun};
use decomp::spec::AlgoSpec;

/// Run one sweep-style cell (ring, uniform 5 Mbit/s + 5 ms links, modeled
/// compute) end to end at the given shard count.
fn run_cell(algo: AlgoSpec, compressor: &str, n: usize, shards: usize) -> SimRun {
    let iters = 25usize;
    let entry = algo.entry();
    let cfg = TrainConfig {
        algo: entry.canonical.into(),
        compressor: compressor.into(),
        topology: "ring".into(),
        n_nodes: n,
        model: "quadratic".into(),
        dim: 32,
        rows_per_node: 8,
        backend: "sim".into(),
        eta: 0.5,
        seed: 0x5a7d,
        ..Default::default()
    };
    let algo_cfg = cfg.build_algo_config().expect("admissible cell");
    let (models, x0) = cfg.build_models().expect("models");
    let programs: Vec<_> = models
        .into_iter()
        .enumerate()
        .map(|(node, model)| (entry.make_program)(&algo_cfg, node, model, &x0, 0.05, iters))
        .collect();
    let opts = SimOpts {
        cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
        staleness: None,
        compute_per_iter_s: 0.01,
        scenario: None,
    };
    let links = LinkTable::for_pattern(entry.comm, &algo_cfg.mixing.graph).expect("link table");
    let engine = SimEngine::with_links(n, opts, links, shards);
    run_sim_on(engine, programs, iters)
}

/// Bitwise comparison of two runs: iterates, losses, per-node byte
/// counters, global frame accounting, and the virtual clock.
fn assert_runs_identical(a: &SimRun, b: &SimRun, what: &str) {
    assert_eq!(
        a.virtual_time_s.to_bits(),
        b.virtual_time_s.to_bits(),
        "{what}: virtual time"
    );
    assert_eq!(a.payload_bytes, b.payload_bytes, "{what}: payload bytes");
    assert_eq!(a.frame_bytes, b.frame_bytes, "{what}: frame bytes");
    assert_eq!(a.frames, b.frames, "{what}: frames");
    assert_eq!(a.reports.len(), b.reports.len());
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.bytes_sent, rb.bytes_sent, "{what}: node {} bytes", ra.node);
        assert_eq!(ra.msgs_sent, rb.msgs_sent, "{what}: node {} msgs", ra.node);
        let xa: Vec<u32> = ra.final_x.iter().map(|v| v.to_bits()).collect();
        let xb: Vec<u32> = rb.final_x.iter().map(|v| v.to_bits()).collect();
        assert_eq!(xa, xb, "{what}: node {} final iterate", ra.node);
        let la: Vec<u64> = ra.losses.iter().map(|v| v.to_bits()).collect();
        let lb: Vec<u64> = rb.losses.iter().map(|v| v.to_bits()).collect();
        assert_eq!(la, lb, "{what}: node {} losses", ra.node);
    }
}

#[test]
fn choco_topk_is_bit_identical_at_any_shard_count() {
    // Gossip over the graph-edge link table. n = 10 with 4 shards gives
    // uneven ranges (2/3/2/3), exercising the split_at_mut carve-up.
    let serial = run_cell(AlgoSpec::Choco, "topk_25", 10, 1);
    for shards in [2, 4] {
        let sharded = run_cell(AlgoSpec::Choco, "topk_25", 10, shards);
        assert_runs_identical(&serial, &sharded, &format!("choco_topk25 @ {shards} shards"));
    }
    // Sanity: the cell actually communicated and made progress.
    assert!(serial.frame_bytes > 0);
    assert!(serial.virtual_time_s > 0.0);
}

#[test]
fn qallreduce_hub_is_bit_identical_at_any_shard_count() {
    // Hub-rooted reduction over the star link table: node 0's absorb is
    // the heavy one (n−1 expected messages), and it sits alone at the
    // start of shard 0's slot range.
    let serial = run_cell(AlgoSpec::Qallreduce, "q8", 9, 1);
    for shards in [2, 4] {
        let sharded = run_cell(AlgoSpec::Qallreduce, "q8", 9, shards);
        assert_runs_identical(&serial, &sharded, &format!("qallreduce_q8 @ {shards} shards"));
    }
    assert!(serial.frame_bytes > 0);
}
