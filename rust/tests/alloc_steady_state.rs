//! The zero-allocation contract of the simulation hot path.
//!
//! `SimEngine::step` must perform **zero heap allocations after warm-up**
//! for the `dpsgd_fp32@n64` configuration (the fig3/bench sweep cell)
//! and for `choco_lowrank_r4@n64` (the link-state compressor family —
//! its power-iteration factors and decode scratch are sized once at link
//! build, and factor payloads cycle through the `Outbox` wire pool):
//! every per-phase structure — arrival heap, link-keyed delivery slots,
//! frame shells, wire payload buffers, expects/absorb scratch — is
//! persistent and pooled, so steady-state iterations only move bytes.
//! The same contract is pinned at n = 4096 on the sparse CSR slot table
//! (`SimEngine::with_links`), where a dense plan would not even fit.
//!
//! Asserted with a counting `#[global_allocator]` wrapped around the
//! system allocator. This file intentionally contains a single test
//! (phases run sequentially inside it): a concurrently running test
//! would pollute the global counter.

use decomp::algorithms::AlgoConfig;
use decomp::compression;
use decomp::coordinator::program::build_program;
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::{LinkTable, NodeProgram, SimEngine, SimOpts};
use decomp::obs::CodecCost;
use decomp::spec::{ScenarioRuntime, ScenarioSpec};
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// The `@n64` sweep-cell shape (64-ring, dim-1024 quadratic shards,
/// worst §5.2 condition) on the small-n dense delivery plan.
fn steady_state_allocs(algo: &str, compressor: &str, scenario: &str) -> u64 {
    steady_state_allocs_at(algo, compressor, scenario, 64, 1024, false, false)
}

/// Build an n-node ring cell for one algorithm × compressor, run it to
/// steady state, and return the allocation delta across the post-warm-up
/// iterations. `sparse` routes through the CSR link-keyed slot table
/// (O(edges)) instead of the dense all-pairs plan (O(n²)) — mandatory at
/// n = 4096, where dense slot headers alone would cost a gibibyte.
/// `obs` runs the cell with the counters-level instrumentation plane
/// enabled — its registries are preallocated `u64` cells, so the
/// zero-allocation contract must hold unchanged.
fn steady_state_allocs_at(
    algo: &str,
    compressor: &str,
    scenario: &str,
    n: usize,
    dim: usize,
    sparse: bool,
    obs: bool,
) -> u64 {
    let iters = 25usize;
    let spec = SynthSpec {
        n_nodes: n,
        dim,
        rows_per_node: 8,
        ..Default::default()
    };
    let (models, x0) = build_models(&ModelKind::Quadratic { spread: 1.0, noise: 0.1 }, &spec);
    let (comp, link) = compression::resolve_name(compressor).expect("compressor");
    let mixing = Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n)));
    let sc_spec: ScenarioSpec = scenario.parse().expect("scenario");
    let runtime = if sc_spec.is_static() {
        None
    } else {
        Some(Arc::new(
            ScenarioRuntime::new(&sc_spec, &mixing, 0xf163, None).expect("scenario runtime"),
        ))
    };
    let cfg = AlgoConfig {
        mixing,
        compressor: comp,
        seed: 0xf163,
        eta: if algo == "choco" { 0.4 } else { 1.0 },
        link,
        scenario: runtime.clone(),
    };
    let mut programs: Vec<Box<dyn NodeProgram>> = models
        .into_iter()
        .enumerate()
        .map(|(node, model)| {
            build_program(algo, &cfg, node, model, &x0, 0.05, iters).expect("program")
        })
        .collect();
    let opts = SimOpts {
        cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
        staleness: None,
        compute_per_iter_s: 0.0,
        scenario: runtime,
    };
    let mut engine = if sparse {
        let links = LinkTable::from_graph(&cfg.mixing.graph).expect("ring link table");
        SimEngine::with_links(n, opts, links, 1)
    } else {
        SimEngine::new(n, opts)
    };
    if obs {
        engine.enable_obs(algo, CodecCost::per_elem(4, 1));
    }

    // Warm-up: fills the wire/frame pools, the delivery slots, the
    // arrival heap, and every scratch buffer to steady-state capacity.
    for t in 0..5u64 {
        engine.step(&mut programs, t);
    }

    let before = alloc_count();
    for t in 5..iters as u64 {
        engine.step(&mut programs, t);
    }
    let during = alloc_count() - before;

    // Sanity: the run actually did work (payloads moved, clock advanced).
    assert!(engine.clock().payload_bytes > 0);
    assert!(engine.clock().now() > 0.0);
    let run = engine.finish(programs);
    assert_eq!(run.reports.len(), n);
    for r in &run.reports {
        assert_eq!(r.losses.len(), iters);
    }
    during
}

#[test]
fn sim_step_allocates_nothing_after_warmup_at_n64() {
    // Phases run sequentially inside one test: a concurrently running
    // test would pollute the global allocation counter.
    //
    // dpsgd_fp32@n64 — the fig3/bench sweep cell, pinned since PR 3.
    let d = steady_state_allocs("dpsgd", "fp32", "static");
    assert_eq!(
        d, 0,
        "SimEngine::step allocated {d} time(s) in steady state \
         (expected zero after warm-up for dpsgd_fp32@n64)"
    );
    // choco_lowrank_r4@n64 — the link-state family: power-iteration
    // factors, decode scratch, and the warm-started Q all live in
    // per-link state sized at build, and factor payloads cycle through
    // the Outbox wire pool, so the steady-state contract extends to the
    // strongest compressor in the tree.
    let c = steady_state_allocs("choco", "lowrank_r4", "static");
    assert_eq!(
        c, 0,
        "SimEngine::step allocated {c} time(s) in steady state \
         (expected zero after warm-up for choco_lowrank_r4@n64)"
    );
    // Lossy links must not reopen the allocator: a dropped frame's wires
    // recycle into the outbox pool and its shell into the frame pool at
    // the emit site, and the per-round renormalized weights live in a
    // preallocated scratch — so a 20% drop rate stays allocation-free.
    let p = steady_state_allocs("dpsgd", "fp32", "drop_p20");
    assert_eq!(
        p, 0,
        "SimEngine::step allocated {p} time(s) in steady state \
         (expected zero after warm-up for dpsgd_fp32@n64 under drop_p20)"
    );
    // And the EF family's own-drop path (skip compress, keep residual)
    // is equally allocation-free.
    let e = steady_state_allocs("deepsqueeze", "q4", "drop_p20");
    assert_eq!(
        e, 0,
        "SimEngine::step allocated {e} time(s) in steady state \
         (expected zero after warm-up for deepsqueeze_q4@n64 under drop_p20)"
    );
    // n=4096 ring on the sparse CSR slot table: the zero-allocation
    // contract survives the scale jump — slot lookups are binary searches
    // over degree-2 rows, and the pools behave exactly as at n=64.
    let big = steady_state_allocs_at("dpsgd", "fp32", "static", 4096, 64, true, false);
    assert_eq!(
        big, 0,
        "SimEngine::step allocated {big} time(s) in steady state \
         (expected zero after warm-up for dpsgd_fp32@n4096 on sparse slots)"
    );
    // ... including the drop path at that scale (PR 6's lossy-link pin,
    // re-pinned on the sparse layout).
    let bigp = steady_state_allocs_at("dpsgd", "fp32", "drop_p20", 4096, 64, true, false);
    assert_eq!(
        bigp, 0,
        "SimEngine::step allocated {bigp} time(s) in steady state \
         (expected zero after warm-up for dpsgd_fp32@n4096 under drop_p20)"
    );
    // The instrumentation plane's own acceptance pin: counters-level
    // observation is registries of preallocated u64 cells, so enabling
    // it must not reopen the allocator — neither on the stateless-codec
    // cell nor on the link-state compressor with a nonzero codec cost.
    let o = steady_state_allocs_at("dpsgd", "q8", "static", 64, 1024, false, true);
    assert_eq!(
        o, 0,
        "SimEngine::step allocated {o} time(s) in steady state \
         (expected zero after warm-up for observed dpsgd_q8@n64)"
    );
    let oc = steady_state_allocs_at("choco", "topk_25", "static", 64, 1024, false, true);
    assert_eq!(
        oc, 0,
        "SimEngine::step allocated {oc} time(s) in steady state \
         (expected zero after warm-up for observed choco_topk_25@n64)"
    );
}
