//! The zero-allocation contract of the simulation hot path.
//!
//! `SimEngine::step` must perform **zero heap allocations after warm-up**
//! for the `dpsgd_fp32@n64` configuration (the fig3/bench sweep cell):
//! every per-phase structure — arrival heap, flat delivery slots, frame
//! shells, wire payload buffers, expects/absorb scratch — is persistent
//! and pooled, so steady-state iterations only move bytes.
//!
//! Asserted with a counting `#[global_allocator]` wrapped around the
//! system allocator. This file intentionally contains a single test:
//! a concurrently running test would pollute the global counter.

use decomp::algorithms::AlgoConfig;
use decomp::compression;
use decomp::coordinator::program::build_program;
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::{NodeProgram, SimEngine, SimOpts};
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn sim_step_allocates_nothing_after_warmup_for_dpsgd_fp32_n64() {
    // The dpsgd_fp32@n64 sweep cell: 64-ring, dim-1024 quadratic shards,
    // worst §5.2 network condition — the same shape the fig3 measured
    // sweep and the `sim_virtual_s_per_iter` bench group run.
    let n = 64;
    let iters = 25usize;
    let spec = SynthSpec {
        n_nodes: n,
        dim: 1024,
        rows_per_node: 8,
        ..Default::default()
    };
    let (models, x0) = build_models(&ModelKind::Quadratic { spread: 1.0, noise: 0.1 }, &spec);
    let cfg = AlgoConfig {
        mixing: Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n))),
        compressor: Arc::from(compression::from_name("fp32").expect("compressor")),
        seed: 0xf163,
        eta: 1.0,
    };
    let mut programs: Vec<Box<dyn NodeProgram>> = models
        .into_iter()
        .enumerate()
        .map(|(node, model)| {
            build_program("dpsgd", &cfg, node, model, &x0, 0.05, iters).expect("program")
        })
        .collect();
    let mut engine = SimEngine::new(
        n,
        SimOpts {
            cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
            compute_per_iter_s: 0.0,
        },
    );

    // Warm-up: fills the wire/frame pools, the delivery slots, the
    // arrival heap, and every scratch buffer to steady-state capacity.
    for t in 0..5u64 {
        engine.step(&mut programs, t);
    }

    let before = alloc_count();
    for t in 5..iters as u64 {
        engine.step(&mut programs, t);
    }
    let during = alloc_count() - before;
    assert_eq!(
        during, 0,
        "SimEngine::step allocated {during} time(s) in steady state \
         (expected zero after warm-up for dpsgd_fp32@n64)"
    );

    // Sanity: the run actually did work (payloads moved, clock advanced).
    assert!(engine.clock().payload_bytes > 0);
    assert!(engine.clock().now() > 0.0);
    let run = engine.finish(programs);
    assert_eq!(run.reports.len(), n);
    for r in &run.reports {
        assert_eq!(r.losses.len(), iters);
    }
}
