//! Integration pins for bounded-staleness execution and the adaptive
//! per-link controller (DESIGN.md §4b).
//!
//! What the executor promises and this file enforces through public API
//! only:
//!
//! - a quorum that resolves to *all* neighbors (`quorum_q99` on a
//!   degree-2 ring) routes through the bounded-delivery machinery yet is
//!   **bitwise identical** to the bulk-synchronous engine — virtual
//!   clock, per-node losses, final iterates, byte/frame accounting —
//!   for every staleness-safe cell including the adaptive controller;
//! - a genuinely bounded quorum defers frames, folds every one it
//!   applies late, never invents one (`StaleApplied ≤ StaleDeferred`),
//!   and stays **bit-identical across event-loop shard counts** and
//!   across repeats;
//! - relaxing the barrier can only shrink the makespan: for fixed-size
//!   codecs the frame timings are value-independent, so the bounded
//!   clock is pointwise ≤ the synchronous clock;
//! - the error-feedback late-fold path survives composition with
//!   per-link drops (`dropln_pP`): the run completes (the sender/
//!   receiver drop-agreement protocol holds under deferral), the
//!   staleness machinery engages, and the EF cell still converges;
//! - the tentpole acceptance pin: on the worst §5.2 cell the adaptive
//!   controller reaches its target loss in strictly less virtual time
//!   than every static member of the EF family.

use decomp::algorithms::{AlgoConfig, RunOpts};
use decomp::compression;
use decomp::coordinator::program::build_program;
use decomp::coordinator::ObsSettings;
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::network::cost::{CostModel, NetCondition, NetworkModel};
use decomp::network::sim::{LinkTable, NodeProgram, SimEngine, SimOpts, SimRun, Staleness};
use decomp::obs::{CodecCost, Ctr};
use decomp::spec::{ExperimentSpec, ObsSpec};
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::sync::Arc;

/// The §5.2 worst condition's shape (5 Mbps / 5 ms) — communication
/// dominates, so barrier discipline is what the clock measures.
fn worst_cost() -> CostModel {
    CostModel::Uniform(NetworkModel::new(5e6, 5e-3))
}

/// One staleness-safe cell on a 16-node ring through the full spec
/// layer (admission, timing bind, staleness injection).
fn ring_cell(algo: &str, comp: &str, eta: f32, staleness: &str) -> SimRun {
    let n = 16;
    let spec = SynthSpec {
        n_nodes: n,
        dim: 64,
        rows_per_node: 8,
        ..Default::default()
    };
    let (models, x0) = build_models(&ModelKind::Quadratic { spread: 1.0, noise: 0.1 }, &spec);
    let exp = ExperimentSpec::parse(algo, comp, "ring", n, 0x57a1e, eta)
        .unwrap_or_else(|e| panic!("{e}"))
        .with_staleness(staleness)
        .unwrap_or_else(|e| panic!("{e}"));
    let sim = SimOpts {
        cost: worst_cost(),
        compute_per_iter_s: 0.001,
        scenario: None,
        staleness: None,
    };
    exp.session()
        .unwrap_or_else(|e| panic!("{e}"))
        .run_simulated(models, &x0, 0.05, 10, sim)
        .unwrap_or_else(|e| panic!("{algo}/{comp}: {e}"))
}

/// Bitwise equality over everything a `SimRun` reports.
fn assert_runs_bitwise_equal(a: &SimRun, b: &SimRun, label: &str) {
    assert_eq!(
        a.virtual_time_s.to_bits(),
        b.virtual_time_s.to_bits(),
        "{label}: virtual time {} vs {}",
        a.virtual_time_s,
        b.virtual_time_s
    );
    assert_eq!(a.payload_bytes, b.payload_bytes, "{label}: payload bytes");
    assert_eq!(a.frame_bytes, b.frame_bytes, "{label}: frame bytes");
    assert_eq!(a.frames, b.frames, "{label}: frames");
    assert_eq!(a.frames_dropped, b.frames_dropped, "{label}: drops");
    assert_eq!(a.reports.len(), b.reports.len(), "{label}: node count");
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.bytes_sent, rb.bytes_sent, "{label}: node {} bytes", ra.node);
        assert_eq!(ra.msgs_sent, rb.msgs_sent, "{label}: node {} msgs", ra.node);
        assert_eq!(ra.losses.len(), rb.losses.len(), "{label}: node {} losses", ra.node);
        for (t, (la, lb)) in ra.losses.iter().zip(&rb.losses).enumerate() {
            assert_eq!(
                la.to_bits(),
                lb.to_bits(),
                "{label}: node {} loss at iter {t}: {la} vs {lb}",
                ra.node
            );
        }
        for (i, (xa, xb)) in ra.final_x.iter().zip(&rb.final_x).enumerate() {
            assert_eq!(
                xa.to_bits(),
                xb.to_bits(),
                "{label}: node {} param {i}: {xa} vs {xb}",
                ra.node
            );
        }
    }
}

#[test]
fn full_quorum_staleness_is_bitwise_identical_to_the_bulk_synchronous_engine() {
    // On a degree-2 ring, `quorum_q99` needs ⌈2·99/100⌉ = 2 arrivals —
    // all of them — so the bounded executor's release points coincide
    // with the bulk barrier and its partial-absorb path sees the
    // complete neighbor set every phase. The runs must agree bit for
    // bit, for every staleness-safe family member including both
    // link-state cells (low-rank and the adaptive controller).
    for (algo, comp, eta) in [
        ("choco", "q8", 0.5),
        ("choco", "sign", 0.4),
        ("choco", "topk_25", 0.4),
        ("choco", "lowrank_r2", 0.4),
        ("choco", "adapt_b2_8", 0.5),
        ("deepsqueeze", "q4", 1.0),
        ("deepsqueeze", "topk_25", 0.4),
    ] {
        let sync = ring_cell(algo, comp, eta, "sync");
        let quorum_all = ring_cell(algo, comp, eta, "quorum_q99_s1");
        assert_runs_bitwise_equal(&sync, &quorum_all, &format!("{algo}/{comp}"));
        assert!(
            sync.reports.iter().all(|r| r.losses.iter().all(|l| l.is_finite())),
            "{algo}/{comp}: non-finite loss"
        );
    }
}

/// One bounded-staleness choco/q4 run on an irregular random graph at
/// the given event-loop shard count, instrumented so the deferral
/// counters are visible. Node degrees differ, so senders' NIC
/// serialization staggers arrival times and a 50% quorum genuinely
/// defers frames.
fn sharded_bounded_run(shards: usize, staleness: Option<Staleness>) -> SimRun {
    let n = 12;
    let iters = 12usize;
    let spec = SynthSpec {
        n_nodes: n,
        dim: 32,
        rows_per_node: 8,
        ..Default::default()
    };
    let (models, x0) = build_models(&ModelKind::Quadratic { spread: 1.0, noise: 0.1 }, &spec);
    let (comp, link) = compression::resolve_name("q4").expect("compressor");
    let graph = Graph::build(Topology::Random { p_percent: 35, seed: 9 }, n);
    let mixing = Arc::new(MixingMatrix::metropolis(graph));
    let cfg = AlgoConfig {
        mixing,
        compressor: comp,
        seed: 0x57a1e5,
        eta: 0.5,
        link,
        scenario: None,
    };
    let mut programs: Vec<Box<dyn NodeProgram>> = models
        .into_iter()
        .enumerate()
        .map(|(node, model)| {
            build_program("choco", &cfg, node, model, &x0, 0.05, iters).expect("program")
        })
        .collect();
    let opts = SimOpts {
        cost: worst_cost(),
        compute_per_iter_s: 0.0,
        scenario: None,
        staleness,
    };
    let links = LinkTable::from_graph(&cfg.mixing.graph).expect("links");
    let mut engine = SimEngine::with_links(n, opts, links, shards);
    engine.enable_obs("choco_q4", CodecCost::per_elem(2, 1));
    for t in 0..iters as u64 {
        engine.step(&mut programs, t);
    }
    engine.finish(programs)
}

#[test]
fn bounded_quorum_is_bit_identical_across_shards_and_repeats() {
    let st = Some(Staleness { quorum_pct: 50, max_rounds: 2 });
    let base = sharded_bounded_run(1, st);
    let base_obs = base.obs.as_ref().expect("obs enabled");

    // The machinery actually engaged: frames were deferred past the
    // quorum, some were folded late, and none was applied that was
    // never deferred.
    let deferred = base_obs.reg.counter(Ctr::StaleDeferred);
    let applied = base_obs.reg.counter(Ctr::StaleApplied);
    assert!(deferred > 0, "quorum_q50 on an irregular graph must defer frames");
    assert!(applied > 0, "deferred frames must be folded late");
    assert!(applied <= deferred, "folded {applied} > deferred {deferred}");

    // Bit-identical across shard counts — counters included.
    for shards in [2usize, 4] {
        let run = sharded_bounded_run(shards, st);
        assert_runs_bitwise_equal(&base, &run, &format!("{shards} shards"));
        assert_eq!(run.obs.as_ref().unwrap().reg, base_obs.reg, "registry at {shards} shards");
    }
    // And across repeats at the same shard count.
    let again = sharded_bounded_run(1, st);
    assert_runs_bitwise_equal(&base, &again, "repeat");

    // Relaxing the barrier can only shrink the makespan: q4 frames have
    // value-independent sizes, so every arrival and release under the
    // bounded discipline is pointwise ≤ its synchronous counterpart.
    let sync = sharded_bounded_run(1, None);
    assert!(
        base.virtual_time_s <= sync.virtual_time_s,
        "bounded {} > sync {}",
        base.virtual_time_s,
        sync.virtual_time_s
    );
    assert_eq!(sync.obs.as_ref().unwrap().reg.counter(Ctr::StaleDeferred), 0);
}

#[test]
fn ef_late_folds_survive_per_link_drops() {
    // Compose the two delivery perturbations this PR and PR 6 added:
    // bounded staleness (quorum_q50_s2) over lossy links (dropln_p10).
    // Drops skip NIC slots, which staggers the surviving arrivals, so
    // the quorum defers frames from round one even on the symmetric
    // ring; the run must complete (the executor panics by design if the
    // sender/receiver drop-agreement breaks under deferral) and the EF
    // cell must still converge.
    let n = 16;
    let spec = SynthSpec {
        n_nodes: n,
        dim: 64,
        rows_per_node: 8,
        ..Default::default()
    };
    let kind = ModelKind::Quadratic { spread: 1.0, noise: 0.1 };
    let (models, x0) = build_models(&kind, &spec);
    let (eval_models, _) = build_models(&kind, &spec);
    let exp = ExperimentSpec::parse("choco", "topk_25", "ring", n, 0xd5a1e, 0.4)
        .unwrap()
        .with_scenario("dropln_p10")
        .unwrap()
        .with_staleness("quorum_q50_s2")
        .unwrap();
    let session = exp.session().unwrap_or_else(|e| panic!("{e}"));
    let opts = RunOpts {
        iters: 24,
        gamma: 0.05,
        eval_every: 6,
        ..RunOpts::default()
    };
    let sim = SimOpts {
        cost: worst_cost(),
        compute_per_iter_s: 0.0,
        scenario: None,
        staleness: None,
    };
    let obs_on = ObsSettings {
        spec: ObsSpec::Counters,
        trace_out: None,
    };
    let traced = session
        .run_sim_traced(models, &eval_models, &x0, &opts, sim, obs_on)
        .expect("staleness + drops run completes");
    let obs = traced.run.obs.as_ref().expect("counters on");

    assert!(traced.run.frames_dropped > 0, "dropln_p10 must condemn frames");
    let deferred = obs.reg.counter(Ctr::StaleDeferred);
    let applied = obs.reg.counter(Ctr::StaleApplied);
    assert!(deferred > 0, "drop-staggered arrivals must trip the quorum");
    assert!(applied > 0 && applied <= deferred, "folded {applied} vs deferred {deferred}");

    // The EF residual machinery still does its job under both
    // perturbations at once: losses stay finite and the cell descends.
    let pts = &traced.trace.points;
    assert!(pts.iter().all(|p| p.global_loss.is_finite()));
    let first = pts.first().unwrap().global_loss;
    let last = pts.last().unwrap().global_loss;
    assert!(last < first, "EF cell must descend: {first} -> {last}");
    for w in pts.windows(2) {
        assert!(w[1].bytes_sent >= w[0].bytes_sent, "byte counter must be monotone");
        assert!(w[1].sim_time_s >= w[0].sim_time_s, "virtual clock must be monotone");
    }
}

#[test]
fn adaptive_controller_beats_every_static_family_member_on_the_worst_cell() {
    // The tentpole acceptance pin, at integration level (the unit twin
    // lives in `experiments::adapt_sweep`): on the worst §5.2 condition
    // the adaptive cell reaches its own 75%-horizon target loss in
    // strictly less virtual time than every static EF-family member.
    use decomp::experiments::adapt_sweep::sweep_condition;
    let rows = sweep_condition(120, true, NetCondition::Worst);
    let adaptive = rows.last().expect("adaptive row present");
    assert_eq!(adaptive.algo, "choco_adapt_b2_8");
    let target = adaptive.best_loss_at(0.75);
    let t_adapt = adaptive.time_to(target).expect("adaptive reaches its own target");
    for r in &rows[..rows.len() - 1] {
        if let Some(t) = r.time_to(target) {
            assert!(
                t_adapt < t,
                "{}: static reached target {target:.5} in {t:.3}s vs adaptive {t_adapt:.3}s",
                r.algo
            );
        }
    }
}
