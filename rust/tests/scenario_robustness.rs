//! The PR's headline claim, as a test: under node churn and lossy links,
//! the error-feedback family (CHOCO-SGD, DeepSqueeze) recovers to within
//! tolerance of its fault-free loss, while the replica/estimate family
//! (DCD, ECD) visibly degrades — their compressed-delta state has no
//! recovery path across a rejoin, so every missed update is a permanent
//! offset.
//!
//! Layout mirrors the scenariosweep cells: n = 64 ring, logistic dim-64
//! workload, seed 0x5c40 (a seed whose sampled 10% churn set leaves every
//! live ring node at least one live neighbor), fault schedule
//! `churn_p10_l30_j75 + drop_p1` — 6 of 64 nodes frozen over t ∈ [30, 75)
//! plus 1% whole-broadcast drops throughout, with 125 post-rejoin
//! iterations to recover in.
//!
//! Also pinned here: every scenario cell is bit-identical across repeats
//! and across sweep-runner thread counts (the determinism contract that
//! makes the sweep's grid trustworthy).

use decomp::data::ModelKind;
use decomp::experiments::runner;
use decomp::experiments::scenario_sweep::{run_cell, ScenarioRow, CHURN};

const N: usize = 64;
const DIM: usize = 64;
const ITERS: usize = 200;
const TOLERANCE: f64 = 0.15;

fn kind() -> ModelKind {
    ModelKind::Logistic { batch: 8 }
}

fn faulty_scenario() -> String {
    format!("{CHURN}+drop_p1")
}

/// Relative degradation of the faulty cell over its static reference.
/// Non-finite faulty losses count as infinite degradation — a diverged
/// run must never pass as "within tolerance".
fn degradation(faulty: &ScenarioRow, reference: &ScenarioRow) -> f64 {
    assert!(
        reference.final_loss.is_finite() && reference.final_loss > 0.0,
        "static reference for {} broken: {}",
        reference.algo,
        reference.final_loss
    );
    if !faulty.final_loss.is_finite() {
        return f64::INFINITY;
    }
    (faulty.final_loss - reference.final_loss) / reference.final_loss
}

fn pair(algo: &str, comp: &str, eta: f32) -> (ScenarioRow, ScenarioRow) {
    let st = run_cell(N, DIM, ITERS, &kind(), algo, comp, eta, "static");
    let faulty = run_cell(N, DIM, ITERS, &kind(), algo, comp, eta, &faulty_scenario());
    (st, faulty)
}

#[test]
fn error_feedback_family_rides_out_churn_and_drops() {
    for (algo, comp, eta) in [("choco", "topk_25", 0.4), ("deepsqueeze", "q4", 0.4)] {
        let (st, faulty) = pair(algo, comp, eta);
        let d = degradation(&faulty, &st);
        assert!(
            d <= TOLERANCE,
            "{algo}_{comp} under {} degraded {:.1}% over static ({} vs {}) — \
             the EF residual should have absorbed the faults",
            faulty_scenario(),
            d * 100.0,
            faulty.final_loss,
            st.final_loss
        );
    }
}

#[test]
fn replica_family_visibly_degrades_under_the_same_faults() {
    for (algo, comp) in [("dcd", "q8"), ("ecd", "q8")] {
        let (st, faulty) = pair(algo, comp, 1.0);
        let d = degradation(&faulty, &st);
        assert!(
            d > TOLERANCE,
            "{algo}_{comp} under {} only degraded {:.1}% over static ({} vs {}) — \
             stale replicas were expected to leave a visible permanent offset",
            faulty_scenario(),
            d * 100.0,
            faulty.final_loss,
            st.final_loss
        );
    }
}

#[test]
fn scenario_cells_are_bit_identical_across_repeats() {
    let sc = faulty_scenario();
    let a = run_cell(N, DIM, 60, &kind(), "choco", "sign", 0.4, &sc);
    let b = run_cell(N, DIM, 60, &kind(), "choco", "sign", 0.4, &sc);
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
    assert_eq!(a.init_loss.to_bits(), b.init_loss.to_bits());
    assert_eq!(a.virtual_s.to_bits(), b.virtual_s.to_bits());
}

#[test]
fn sweep_grid_is_bit_identical_at_any_thread_count() {
    let sc = faulty_scenario();
    let cells: Vec<(&str, &str, f32)> = vec![
        ("dpsgd", "fp32", 1.0),
        ("choco", "topk_25", 0.4),
        ("deepsqueeze", "q4", 0.4),
        ("dcd", "q8", 1.0),
    ];
    let run = |threads: usize| {
        runner::run_cells_on(threads, &cells, |_, (algo, comp, eta)| {
            run_cell(16, 16, 40, &kind(), algo, comp, *eta, &sc).final_loss
        })
    };
    let serial = run(1);
    let parallel = run(4);
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&serial), bits(&parallel));
}
