//! End-to-end integration: full training runs through the public API
//! (TrainConfig → threaded coordinator), covering every algorithm ×
//! model × topology combination at small scale, plus failure-injection
//! checks on the config surface.

use decomp::algorithms::{self, RunOpts};
use decomp::coordinator::{run_threaded, TrainConfig};

fn run_cfg(cfg: &TrainConfig) -> anyhow::Result<(f64, f64)> {
    let algo_cfg = cfg.build_algo_config()?;
    let (models, x0) = cfg.build_models()?;
    let (eval, _) = cfg.build_models()?;
    let run = run_threaded(&cfg.algo, &algo_cfg, models, &x0, cfg.gamma, cfg.iters)?;
    let mean = run.mean_params();
    let init: f64 = eval.iter().map(|m| m.full_loss(&x0)).sum::<f64>() / eval.len() as f64;
    let fin: f64 = eval.iter().map(|m| m.full_loss(&mean)).sum::<f64>() / eval.len() as f64;
    Ok((init, fin))
}

#[test]
fn all_algorithms_train_logistic_on_ring() {
    for algo in ["dpsgd", "dcd", "ecd", "naive", "allreduce"] {
        let cfg = TrainConfig {
            algo: algo.into(),
            n_nodes: 6,
            iters: 200,
            gamma: 0.05,
            dim: 32,
            rows_per_node: 64,
            ..Default::default()
        };
        let (init, fin) = run_cfg(&cfg).unwrap();
        assert!(
            fin < 0.8 * init,
            "{algo}: expected progress, {init} -> {fin}"
        );
    }
}

#[test]
fn all_models_train_with_dcd_q8() {
    for model in ["quadratic", "linear", "logistic", "mlp"] {
        let cfg = TrainConfig {
            algo: "dcd".into(),
            model: model.into(),
            n_nodes: 4,
            iters: 150,
            gamma: if model == "mlp" { 0.1 } else { 0.05 },
            dim: 16,
            rows_per_node: 64,
            batch: 4,
            ..Default::default()
        };
        let (init, fin) = run_cfg(&cfg).unwrap();
        assert!(
            fin < init,
            "{model}: expected progress, {init} -> {fin}"
        );
    }
}

#[test]
fn all_topologies_train_with_ecd_q8() {
    for (topo, n) in [("ring", 8), ("full", 8), ("chain", 6), ("star", 6), ("hypercube", 8)] {
        let cfg = TrainConfig {
            algo: "ecd".into(),
            topology: topo.into(),
            n_nodes: n,
            iters: 200,
            gamma: 0.05,
            dim: 32,
            rows_per_node: 64,
            ..Default::default()
        };
        let (init, fin) = run_cfg(&cfg).unwrap();
        assert!(fin < init, "{topo}: {init} -> {fin}");
    }
}

#[test]
fn simulator_and_coordinator_agree_through_public_config() {
    let cfg = TrainConfig {
        algo: "dcd".into(),
        n_nodes: 5,
        iters: 30,
        gamma: 0.05,
        dim: 24,
        rows_per_node: 32,
        ..Default::default()
    };
    // Simulator path.
    let algo_cfg = cfg.build_algo_config().unwrap();
    let (mut sim_models, x0) = cfg.build_models().unwrap();
    let mut sim = algorithms::from_name(&cfg.algo, algo_cfg, &x0, cfg.n_nodes).unwrap();
    for _ in 0..cfg.iters {
        sim.step(&mut sim_models, cfg.gamma);
    }
    // Threaded path (fresh but identical config).
    let algo_cfg2 = cfg.build_algo_config().unwrap();
    let (thr_models, _) = cfg.build_models().unwrap();
    let run = run_threaded(&cfg.algo, &algo_cfg2, thr_models, &x0, cfg.gamma, cfg.iters).unwrap();
    for (a, b) in sim.params().iter().zip(run.final_params()) {
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

#[test]
fn trace_driver_reports_monotone_bytes_and_time() {
    let cfg = TrainConfig {
        algo: "dcd".into(),
        iters: 60,
        ..Default::default()
    };
    let algo_cfg = cfg.build_algo_config().unwrap();
    let (mut models, x0) = cfg.build_models().unwrap();
    let mut algo = algorithms::from_name(&cfg.algo, algo_cfg, &x0, cfg.n_nodes).unwrap();
    let trace = algorithms::run_training(
        algo.as_mut(),
        &mut models,
        &RunOpts {
            iters: 60,
            gamma: 0.05,
            eval_every: 20,
            net: Some(decomp::network::cost::NetworkModel::new(1e8, 1e-3)),
            compute_per_iter_s: 0.01,
            decay_tau: None,
        },
    );
    for w in trace.points.windows(2) {
        assert!(w[1].bytes_sent > w[0].bytes_sent);
        assert!(w[1].sim_time_s > w[0].sim_time_s);
        assert!(w[1].iter > w[0].iter);
    }
}

#[test]
fn choco_lowrank_trains_through_public_config() {
    // The link-state compressor family end to end on the threaded
    // backend: TrainConfig string → link spec → per-node warm-started
    // state → real wire messages.
    let cfg = TrainConfig {
        algo: "choco".into(),
        compressor: "lowrank_r2".into(),
        eta: 0.4,
        n_nodes: 6,
        iters: 200,
        gamma: 0.05,
        dim: 32,
        rows_per_node: 64,
        ..Default::default()
    };
    let (init, fin) = run_cfg(&cfg).unwrap();
    assert!(fin < init, "choco+lowrank should train: {init} -> {fin}");
}

// NOTE: the per-combination rejection tests that used to live here
// (lowrank-outside-choco, biased-for-DCD/ECD) are subsumed by the
// exhaustive rejection matrix in `rust/tests/spec_registry.rs`.

// ---------------------------------------------------------------------
// Failure injection: bad configs fail loudly, never silently.

#[test]
fn bad_algorithm_name_fails() {
    // The typed spec layer rejects the name at config-build time, with
    // the registered list in the message…
    let cfg = TrainConfig {
        algo: "sgd9000".into(),
        ..Default::default()
    };
    let err = cfg.build_algo_config().unwrap_err().to_string();
    assert!(err.contains("registered") && err.contains("dpsgd"), "{err}");
    // …and a hand-built config still fails at the runner.
    let ok = TrainConfig::default();
    let algo_cfg = ok.build_algo_config().unwrap();
    let (models, x0) = ok.build_models().unwrap();
    assert!(run_threaded("sgd9000", &algo_cfg, models, &x0, 0.1, 5).is_err());
}

#[test]
fn bad_compressor_fails() {
    let cfg = TrainConfig {
        compressor: "zstd".into(),
        ..Default::default()
    };
    assert!(cfg.build_algo_config().is_err());
}

#[test]
fn bad_topology_fails() {
    let cfg = TrainConfig {
        topology: "smallworld".into(),
        ..Default::default()
    };
    assert!(cfg.build_mixing().is_err());
}

#[test]
fn topology_size_mismatches_fail_cleanly() {
    // The spec layer pre-validates (topology, n) pairings, so bad sizes
    // reaching from CLI/config input are clean errors, not panics.
    for (topo, n, needle) in [
        ("hypercube", 6, "2^d"),
        ("torus_4x4", 8, "n = 16"),
        ("torus_2x4", 8, ">= 3"),
    ] {
        let cfg = TrainConfig {
            topology: topo.into(),
            n_nodes: n,
            ..Default::default()
        };
        let err = cfg.build_mixing().unwrap_err().to_string();
        assert!(err.contains(needle), "{topo}/n={n}: '{err}'");
    }
}

#[test]
fn model_count_mismatch_fails() {
    let cfg = TrainConfig::default();
    let algo_cfg = cfg.build_algo_config().unwrap();
    let (mut models, x0) = cfg.build_models().unwrap();
    models.pop(); // one model short
    assert!(run_threaded("dcd", &algo_cfg, models, &x0, 0.1, 5).is_err());
}

#[test]
fn config_file_round_trip_via_cli_surface() {
    // Write a config file, load it, train 20 iters — exercises the same
    // path as `decomp train --config ...`.
    let path = std::env::temp_dir().join(format!("decomp_e2e_{}.json", std::process::id()));
    std::fs::write(
        &path,
        r#"{"algo":"ecd","n_nodes":4,"compressor":"q8","iters":20,"gamma":0.05,"dim":16,"rows_per_node":32}"#,
    )
    .unwrap();
    let cfg = decomp::config::load_config(&path).unwrap();
    assert_eq!(cfg.algo, "ecd");
    let (init, fin) = run_cfg(&cfg).unwrap();
    assert!(fin <= init);
    std::fs::remove_file(path).ok();
}
