//! Integration test for the `decomp serve` job loop: two jobs and one
//! malformed line through a single serve session, asserting the
//! streamed frame sequence, per-job id correlation, clean continuation
//! after the bad line, and determinism across repeat runs and thread
//! counts.

use decomp::serve::{serve, ServeOpts, ServeStats};
use decomp::util::json::Json;
use std::io::Cursor;

const GRID_JOB: &str = r#"{"id":"grid","algos":["dpsgd","dcd"],"compressors":["q8"],
    "nodes":4,"iters":4,"eval_every":2,"dim":8,"rows_per_node":16,"batch":4,
    "model":"quadratic"}"#;
const BAD_JOB: &str = r#"{"id":"bad-job","algoz":["dpsgd"]}"#;
const TRACED_JOB: &str = r#"{"id":"traced","algo":"dcd","compressor":"q8",
    "nodes":4,"iters":4,"eval_every":2,"dim":8,"rows_per_node":16,"batch":4,
    "model":"quadratic","trace":true}"#;
const OBS_JOB: &str = r#"{"id":"obs","algo":"choco","compressor":"topk_25",
    "nodes":4,"iters":4,"eval_every":2,"dim":8,"rows_per_node":16,"batch":4,
    "model":"quadratic","obs":true}"#;

fn session() -> String {
    // The raw literals are wrapped for line width; a job must be ONE line.
    let one = |s: &str| s.replace('\n', " ");
    format!("{}\n{}\n{}\n", one(GRID_JOB), one(BAD_JOB), one(TRACED_JOB))
}

fn run(input: &str, threads: usize) -> (ServeStats, String) {
    let mut out = Vec::new();
    let stats = serve(Cursor::new(input), &mut out, &ServeOpts { threads }).unwrap();
    (stats, String::from_utf8(out).unwrap())
}

fn frames(raw: &str) -> Vec<Json> {
    raw.lines()
        .map(|l| Json::parse(l).expect("every frame is one valid JSON line"))
        .collect()
}

fn field<'a>(f: &'a Json, key: &str) -> &'a Json {
    f.get(key).unwrap_or_else(|| panic!("frame missing {key}: {f:?}"))
}

#[test]
fn two_jobs_and_a_malformed_line_stream_the_expected_frames() {
    let (stats, raw) = run(&session(), 1);
    assert_eq!(
        stats,
        ServeStats {
            jobs_ok: 2,
            jobs_rejected: 1,
            jobs_cancelled: 0,
            cells_run: 3
        }
    );

    let frames = frames(&raw);
    let events: Vec<&str> = frames
        .iter()
        .map(|f| field(f, "event").as_str().unwrap())
        .collect();
    // threads=1 runs cells inline in grid order, so the whole stream is
    // deterministic: job "grid" (2 cells), the rejected line, "traced".
    assert_eq!(
        events,
        vec![
            "accepted", "progress", "result", "progress", "result", "done", // grid
            "error",    // bad-job
            "accepted", "progress", "result", "done", // traced
        ]
    );

    // Every frame of the first job correlates to its id.
    for f in &frames[..6] {
        assert_eq!(field(f, "id").as_str(), Some("grid"), "{f:?}");
    }
    assert_eq!(field(&frames[0], "cells").as_f64(), Some(2.0));
    let grid_algos: Vec<&str> = [&frames[2], &frames[4]]
        .iter()
        .map(|f| field(f, "algo").as_str().unwrap())
        .collect();
    assert_eq!(grid_algos, vec!["dpsgd", "dcd"]);
    for f in [&frames[2], &frames[4]] {
        assert_eq!(field(f, "compressor").as_str(), Some("q8"));
        assert!(field(f, "final_loss").as_f64().unwrap().is_finite());
        assert!(f.get("trace").is_none(), "trace must be opt-in: {f:?}");
    }
    let done = &frames[5];
    assert_eq!(field(done, "cells").as_f64(), Some(2.0));
    assert_eq!(field(done, "failed").as_f64(), Some(0.0));

    // The malformed line is answered, with its id recovered, and the
    // loop keeps serving.
    let err = &frames[6];
    assert_eq!(field(err, "id").as_str(), Some("bad-job"));
    assert!(
        field(err, "error").as_str().unwrap().contains("algoz"),
        "error should name the unknown field: {err:?}"
    );

    // The traced job's result carries the full per-eval trace.
    let traced = &frames[9];
    assert_eq!(field(traced, "id").as_str(), Some("traced"));
    let trace = field(traced, "trace");
    assert_eq!(field(trace, "algo").as_str(), Some("dcd_q8"));
    let points = field(trace, "points").as_arr().unwrap();
    assert!(points.len() >= 2, "iters=4/eval_every=2 should log ≥2 points");
    for p in points {
        assert!(p.get("iter").is_some() && p.get("bytes_sent").is_some(), "{p:?}");
    }
}

#[test]
fn obs_job_reports_per_node_bytes_and_breakdown() {
    let (stats, raw) = run(&format!("{}\n", OBS_JOB.replace('\n', " ")), 1);
    assert_eq!(stats.jobs_ok, 1);
    let frames = frames(&raw);
    let progress = &frames[1];
    let counters = field(progress, "counters");
    assert!(field(counters, "frames").as_f64().unwrap() > 0.0);
    assert_eq!(field(counters, "frames_dropped").as_f64(), Some(0.0));

    let result = &frames[2];
    let by_node = field(result, "bytes_by_node").as_arr().unwrap();
    assert_eq!(by_node.len(), 4, "one bytes entry per node");
    let sum: f64 = by_node.iter().map(|v| v.as_f64().unwrap()).sum();
    assert_eq!(field(result, "bytes_sent").as_f64(), Some(sum));
    assert_eq!(field(result, "frames_dropped").as_f64(), Some(0.0));

    // The embedded breakdown closes: compute + per-phase splits account
    // for the whole virtual clock (up to JSON text round-trip).
    let obs = field(result, "obs");
    let vt = field(obs, "virtual_time_s").as_f64().unwrap();
    let mut total = field(obs, "compute_s").as_f64().unwrap();
    for p in field(obs, "phases").as_arr().unwrap() {
        total += field(p, "serialize_s").as_f64().unwrap();
        total += field(p, "transfer_s").as_f64().unwrap();
        total += field(p, "idle_s").as_f64().unwrap();
    }
    assert!((total - vt).abs() <= 1e-9 * vt.max(1.0), "{total} vs {vt}");
}

#[test]
fn cancel_mid_grid_skips_unstarted_cells_and_ends_with_a_cancelled_frame() {
    // The cancel line sits right behind the job line, so it is already
    // in the reader channel when the first cell completes: with
    // threads=1 the serve loop drains it between cells, cell 1 keeps
    // its frames, cell 2 never starts, and a job queued behind the
    // cancel still runs afterwards.
    let one = |s: &str| s.replace('\n', " ");
    let input = format!(
        "{}\n{{\"cancel\": \"grid\"}}\n{}\n",
        one(GRID_JOB),
        one(TRACED_JOB)
    );
    let (stats, raw) = run(&input, 1);
    assert_eq!(
        stats,
        ServeStats {
            jobs_ok: 1,
            jobs_rejected: 0,
            jobs_cancelled: 1,
            cells_run: 2
        }
    );
    let frames = frames(&raw);
    let events: Vec<&str> = frames
        .iter()
        .map(|f| field(f, "event").as_str().unwrap())
        .collect();
    assert_eq!(
        events,
        vec![
            "accepted", "progress", "result", "cancelled", // grid: cell 1 only
            "accepted", "progress", "result", "done", // traced, replayed after
        ]
    );
    let cancelled = &frames[3];
    assert_eq!(field(cancelled, "id").as_str(), Some("grid"));
    assert_eq!(field(cancelled, "cells").as_f64(), Some(2.0));
    assert_eq!(field(cancelled, "completed").as_f64(), Some(1.0));
    assert_eq!(field(&frames[4], "id").as_str(), Some("traced"));
}

#[test]
fn cancel_before_the_job_line_answers_without_running_anything() {
    let input = format!("{{\"cancel\": \"grid\"}}\n{}\n", GRID_JOB.replace('\n', " "));
    let (stats, raw) = run(&input, 1);
    assert_eq!(stats.jobs_cancelled, 1);
    assert_eq!(stats.cells_run, 0);
    let frames = frames(&raw);
    assert_eq!(frames.len(), 1);
    assert_eq!(field(&frames[0], "event").as_str(), Some("cancelled"));
    assert_eq!(field(&frames[0], "cells").as_f64(), Some(0.0));
    assert_eq!(field(&frames[0], "completed").as_f64(), Some(0.0));
}

#[test]
fn serve_output_is_deterministic() {
    // Same input, same thread count → byte-identical stream.
    let (s1, raw1) = run(&session(), 1);
    let (s2, raw2) = run(&session(), 1);
    assert_eq!(s1, s2);
    assert_eq!(raw1, raw2);

    // More threads may reorder completion, but the set of results (and
    // every trained loss, bitwise) must not change.
    let (s4, raw4) = run(&session(), 4);
    assert_eq!(s4, s1);
    let results = |raw: &str| {
        let mut rs: Vec<(String, f64, u64)> = frames(raw)
            .iter()
            .filter(|f| field(f, "event").as_str() == Some("result"))
            .map(|f| {
                (
                    format!(
                        "{}:{}/{}",
                        field(f, "id").as_str().unwrap(),
                        field(f, "algo").as_str().unwrap(),
                        field(f, "compressor").as_str().unwrap()
                    ),
                    field(f, "final_loss").as_f64().unwrap(),
                    field(f, "bytes_sent").as_f64().unwrap() as u64,
                )
            })
            .collect();
        rs.sort_by(|a, b| a.0.cmp(&b.0));
        rs
    };
    assert_eq!(results(&raw4), results(&raw1));
}
