//! The spec layer's integration contract:
//!
//! 1. **Total round-trips** — every registered algorithm, compressor
//!    family, and topology parses from its own `Display`/`name()` output
//!    (including the parameterized `torus_RxC` / `random_pP_sS` /
//!    `lowrank_rN` strings that used to be unparseable or scattered).
//! 2. **The rejection matrix** — every algorithm × every compressor
//!    family, with hard-coded accept/reject expectations, asserted
//!    against both the one admission function and the public
//!    `TrainConfig` path. This subsumes the per-PR rejection tests the
//!    earlier suites accumulated (biased-for-DCD/ECD, lowrank-outside-
//!    choco, eta range).
//! 3. **Registry ↔ implementation coherence** — every entry constructs
//!    and steps on the sim backend (the same check `decomp list` and the
//!    CI smoke step run).

use decomp::coordinator::TrainConfig;
use decomp::spec::{self, AlgoSpec, CompressorSpec, ScenarioSpec, StalenessSpec, TopologySpec};

#[test]
fn every_algorithm_round_trips_from_str_to_display() {
    for algo in AlgoSpec::ALL {
        let printed = algo.to_string();
        assert_eq!(printed.parse::<AlgoSpec>().unwrap(), algo, "{printed}");
        // Canonical name matches the registry entry.
        assert_eq!(printed, algo.entry().canonical);
    }
    // Every registered alias parses to its entry's spec.
    for entry in spec::REGISTRY.iter() {
        for alias in entry.aliases {
            assert_eq!(alias.parse::<AlgoSpec>().unwrap(), entry.spec, "{alias}");
        }
    }
    // Unknown names list the registry.
    let err = "sgd9000".parse::<AlgoSpec>().unwrap_err().to_string();
    for entry in spec::REGISTRY.iter() {
        assert!(err.contains(entry.canonical), "'{err}' missing {}", entry.canonical);
    }
}

#[test]
fn every_compressor_family_round_trips_from_str_to_display() {
    let instances = [
        CompressorSpec::Fp32,
        CompressorSpec::Quantize { bits: 1 },
        CompressorSpec::Quantize { bits: 2 },
        CompressorSpec::Quantize { bits: 4 },
        CompressorSpec::Quantize { bits: 8 },
        CompressorSpec::Quantize { bits: 16 },
        CompressorSpec::Sparsify { keep_percent: 10 },
        CompressorSpec::Sparsify { keep_percent: 25 },
        CompressorSpec::Sparsify { keep_percent: 50 },
        CompressorSpec::Sparsify { keep_percent: 100 },
        CompressorSpec::TopK { keep_percent: 10 },
        CompressorSpec::TopK { keep_percent: 25 },
        CompressorSpec::Sign,
        CompressorSpec::LowRank { rank: 1 },
        CompressorSpec::LowRank { rank: 2 },
        CompressorSpec::LowRank { rank: 4 },
        CompressorSpec::LowRank { rank: 8 },
        CompressorSpec::LowRank { rank: 64 },
        CompressorSpec::Adaptive { bits_lo: 2, bits_hi: 8 },
        CompressorSpec::Adaptive { bits_lo: 1, bits_hi: 16 },
        CompressorSpec::Adaptive { bits_lo: 4, bits_hi: 5 },
    ];
    for c in instances {
        let printed = c.to_string();
        assert_eq!(printed.parse::<CompressorSpec>().unwrap(), c, "{printed}");
        // The codec (or link spec) the string builds reports the same name,
        // so config strings, metrics, and bench tables can never disagree.
        match c.build_stateless() {
            Some(codec) => assert_eq!(codec.name(), printed),
            None => {
                let link = c.link_spec().expect("non-stateless spec is link-state");
                assert_eq!(link.name(), printed);
            }
        }
    }
    // Legacy aliases still accepted.
    assert_eq!("identity".parse::<CompressorSpec>().unwrap(), CompressorSpec::Fp32);
    // Degenerate adaptive bands are parse errors, not controller panics:
    // the band must be a non-empty range of admissible quantizer widths.
    for bad in ["adapt_b8_2", "adapt_b2_2", "adapt_b0_8", "adapt_b2_17", "adapt_b2"] {
        assert!(bad.parse::<CompressorSpec>().is_err(), "'{bad}' must be rejected");
    }
    // Unknown names list the families.
    let err = "zstd".parse::<CompressorSpec>().unwrap_err().to_string();
    for family in spec::COMPRESSOR_FAMILIES.iter() {
        assert!(err.contains(family.pattern), "'{err}' missing {}", family.pattern);
    }
}

#[test]
fn every_topology_round_trips_name_to_parse() {
    // The former parse gap: `Topology::name()` emitted `torus_RxC` and
    // `random_pP_sS` strings nothing could parse. The round trip is now
    // total over every variant, parameterized ones included.
    let topos = [
        TopologySpec::Ring,
        TopologySpec::FullyConnected,
        TopologySpec::Chain,
        TopologySpec::Star,
        TopologySpec::Hypercube,
        TopologySpec::Torus2d { rows: 3, cols: 5 },
        TopologySpec::Torus2d { rows: 8, cols: 8 },
        TopologySpec::Random { p_percent: 30, seed: 7 },
        TopologySpec::Random { p_percent: 5, seed: 0xdeca },
    ];
    for t in topos {
        assert_eq!(t.to_string(), t.name());
        assert_eq!(t.name().parse::<TopologySpec>().unwrap(), t, "{}", t.name());
    }
    assert_eq!("full".parse::<TopologySpec>().unwrap(), TopologySpec::FullyConnected);
    let err = "moebius".parse::<TopologySpec>().unwrap_err().to_string();
    assert!(err.contains("torus_<r>x<c>") && err.contains("ring"), "{err}");
}

#[test]
fn parameterized_topologies_build_through_train_config() {
    for (topo, n) in [("torus_3x4", 12), ("torus_3x3", 9), ("random_p40_s7", 8)] {
        let cfg = TrainConfig {
            topology: topo.into(),
            n_nodes: n,
            ..Default::default()
        };
        let mixing = cfg.build_mixing().unwrap_or_else(|e| panic!("{topo}: {e}"));
        assert_eq!(mixing.n(), n, "{topo}");
    }
}

/// The rejection matrix: every algorithm × a representative of every
/// compressor family → hard-coded accept/reject. Asserted against the
/// single admission function AND the public `TrainConfig` construction
/// path, so the declarative capability table cannot drift from either.
#[test]
fn rejection_matrix_every_algorithm_times_every_family() {
    // (compressor, unbiased, link_state)
    let compressors = [
        ("fp32", true, false),
        ("q8", true, false),
        ("sparse_p25", true, false),
        ("topk_25", false, false),
        ("sign", false, false),
        ("lowrank_r2", false, true),
        ("adapt_b2_8", true, true),
    ];
    // Hard-coded capability expectations (NOT read from the registry —
    // this is what pins the registry).
    let needs_unbiased = ["dcd", "ecd", "qallreduce"];
    let accepts_link = ["choco"];
    let uses_eta = ["choco", "deepsqueeze"];

    for algo in AlgoSpec::ALL {
        let name = algo.to_string();
        for (comp, unbiased, link_state) in compressors {
            let expect_ok = (unbiased || !needs_unbiased.contains(&name.as_str()))
                && (!link_state || accepts_link.contains(&name.as_str()));
            let eta = if uses_eta.contains(&name.as_str()) { 0.4 } else { 1.0 };

            // (a) the one admission function.
            let admitted =
                spec::admit_spec(algo, &comp.parse::<CompressorSpec>().unwrap(), eta);
            assert_eq!(admitted.is_ok(), expect_ok, "admit: {name}/{comp}");

            // (b) the public TrainConfig path agrees bit for bit.
            let cfg = TrainConfig {
                algo: name.clone(),
                compressor: comp.into(),
                eta,
                ..Default::default()
            };
            let built = cfg.build_algo_config();
            assert_eq!(built.is_ok(), expect_ok, "TrainConfig: {name}/{comp}");

            // (c) rejections carry an actionable message naming the
            // compressor and the violated capability.
            if !expect_ok {
                let err = built.unwrap_err().to_string();
                assert!(
                    err.contains("biased") || err.contains("link-state"),
                    "{name}/{comp}: '{err}'"
                );
                assert!(err.contains(comp), "{name}/{comp}: error must name codec: '{err}'");
            }
        }
    }
}

#[test]
fn eta_range_gated_for_every_algorithm_that_uses_it() {
    for algo in ["choco", "deepsqueeze"] {
        for eta in [0.0f32, -0.5, 1.5] {
            let cfg = TrainConfig {
                algo: algo.into(),
                eta,
                ..Default::default()
            };
            assert!(cfg.build_algo_config().is_err(), "{algo} eta {eta}");
        }
    }
}

#[test]
fn every_scenario_round_trips_from_str_to_display() {
    // Canonical single-part and composed schedules: parse → Display →
    // parse is the identity, and Display emits the normalized part order
    // regardless of the input order.
    let keys = [
        "static",
        "drop_p1",
        "drop_p100",
        "churn_p10_l150_j300",
        "dirichlet_a30",
        "bw_h50_e100",
        "timeout_20",
        "dropln_p7",
        "drop_p2+dropln_p3",
        "churn_p10_l150_j300+drop_p5",
        "churn_p1_l1_j2+drop_p1+dropln_p2+dirichlet_a5+bw_h1_e1+timeout_1",
    ];
    for key in keys {
        let sc: ScenarioSpec = key.parse().unwrap_or_else(|e| panic!("{key}: {e}"));
        assert_eq!(sc.to_string(), key, "Display must be canonical");
        let back: ScenarioSpec = sc.to_string().parse().unwrap();
        assert_eq!(back, sc, "{key}");
    }
    // Aliases and non-canonical part order normalize.
    assert_eq!("none".parse::<ScenarioSpec>().unwrap(), ScenarioSpec::default());
    assert_eq!("static".parse::<ScenarioSpec>().unwrap().to_string(), "static");
    let reordered: ScenarioSpec = "drop_p5+churn_p10_l150_j300".parse().unwrap();
    assert_eq!(reordered.to_string(), "churn_p10_l150_j300+drop_p5");
}

#[test]
fn invalid_scenario_schedules_are_rejected() {
    // The validation matrix: out-of-range percentages, inverted or
    // zero-length churn windows, explicit no-op parts, duplicates,
    // unknown parts, and empty strings all fail to parse.
    let bad = [
        "",
        "+",
        "zombie_p10",
        "churn_p0_l1_j2",      // empty churn set
        "churn_p91_l1_j2",     // > 90% churn
        "churn_p10_l0_j2",     // leave before the first round
        "churn_p10_l5_j5",     // join must follow leave
        "churn_p10_l5_j4",     // inverted window
        "churn_p10_l5",        // missing join
        "drop_p0",             // explicit no-op: spell it 'static'
        "drop_p101",           // > 100%
        "dropln_p0",           // explicit no-op: spell it 'static'
        "dropln_p101",         // > 100%
        "dropln_p1+dropln_p2", // duplicate part
        "dirichlet_a0",        // alpha must be positive
        "bw_h0_e10",           // factor must stay positive
        "bw_h100_e10",         // factor must actually throttle
        "bw_h50_e0",           // zero period
        "timeout_0",           // zero timeout
        "drop_p1+drop_p2",     // duplicate part
        "churn_p10_l1_j2+churn_p10_l3_j4",
        "static+drop_p1",      // 'static' is a whole key, not a part
    ];
    for key in bad {
        assert!(key.parse::<ScenarioSpec>().is_err(), "'{key}' must be rejected");
    }
}

#[test]
fn churn_admission_requires_a_link_state_safe_algorithm() {
    // Hard-coded expectations (NOT read from the registry — this pins
    // the registry): churn needs an error-feedback path to resync after
    // a rejoin; any delivery perturbation excludes the centralized hub
    // protocols; data-only scenarios are admitted for everything.
    let churn_safe = ["dpsgd", "naive", "choco", "deepsqueeze"];
    let hub = ["allreduce", "qallreduce"];
    let churn: ScenarioSpec = "churn_p10_l150_j300".parse().unwrap();
    let drops: ScenarioSpec = "drop_p5".parse().unwrap();
    let data_only: ScenarioSpec = "dirichlet_a30+bw_h50_e100".parse().unwrap();
    for algo in AlgoSpec::ALL {
        let name = algo.to_string();
        let is_safe = churn_safe.contains(&name.as_str());
        let is_hub = hub.contains(&name.as_str());
        assert_eq!(
            spec::admit_scenario(algo, &churn).is_ok(),
            is_safe,
            "churn admission for {name}"
        );
        assert_eq!(
            spec::admit_scenario(algo, &drops).is_ok(),
            !is_hub,
            "drop admission for {name}"
        );
        assert!(spec::admit_scenario(algo, &data_only).is_ok(), "data-only for {name}");
        if !is_safe {
            let err = spec::admit_scenario(algo, &churn).unwrap_err().to_string();
            assert!(err.contains("churn") && err.contains("choco"), "{name}: '{err}'");
        }
    }
}

#[test]
fn every_staleness_spec_round_trips_from_str_to_display() {
    // Parse → Display → parse is the identity over the whole grammar:
    // `sync` and every admissible `quorum_q<pct>_s<rounds>`.
    for key in ["sync", "quorum_q1_s1", "quorum_q50_s2", "quorum_q99_s10"] {
        let st: StalenessSpec = key.parse().unwrap_or_else(|e| panic!("{key}: {e}"));
        assert_eq!(st.to_string(), key, "Display must be canonical");
        assert_eq!(key.parse::<StalenessSpec>().unwrap(), st);
    }
    assert_eq!("sync".parse::<StalenessSpec>().unwrap(), StalenessSpec::SYNC);
    assert!(!StalenessSpec::SYNC.is_bounded());
    assert!("quorum_q50_s2".parse::<StalenessSpec>().unwrap().is_bounded());
    // q100 *is* sync and must be spelled that way (keeps the round trip
    // total); zero quorum, zero bound, and malformed strings reject.
    let bad = [
        "",
        "async",
        "quorum",
        "quorum_q0_s1",
        "quorum_q100_s1",
        "quorum_q50_s0",
        "quorum_q50",
        "quorum_qx_s1",
        "quorum_q50_sx",
    ];
    for key in bad {
        assert!(key.parse::<StalenessSpec>().is_err(), "'{key}' must be rejected");
    }
    // Rejections list the grammar.
    let err = "quorum_q100_s1".parse::<StalenessSpec>().unwrap_err().to_string();
    assert!(err.contains("sync") && err.contains("quorum_q<pct>_s<rounds>"), "{err}");
}

#[test]
fn staleness_admission_requires_a_safe_algorithm_and_no_churn() {
    // Hard-coded expectations (NOT read from the registry — this pins
    // the registry): bounded staleness needs the partial-absorb/
    // late-fold surface only the error-feedback gossip family
    // implements; `sync` is admitted for everything (it *is* the
    // bulk-synchronous path); and bounded staleness never combines with
    // scheduled churn, whose rejoin resync assumes no frames in flight
    // across the rejoin boundary.
    let safe = ["choco", "deepsqueeze"];
    let bounded: StalenessSpec = "quorum_q75_s3".parse().unwrap();
    let churn: ScenarioSpec = "churn_p10_l150_j300".parse().unwrap();
    let drops: ScenarioSpec = "dropln_p5".parse().unwrap();
    let static_sc = ScenarioSpec::default();
    for algo in AlgoSpec::ALL {
        let name = algo.to_string();
        let is_safe = safe.contains(&name.as_str());
        assert!(
            spec::admit_staleness(algo, &StalenessSpec::SYNC, &static_sc).is_ok(),
            "sync admission for {name}"
        );
        // sync + churn passes *this* gate (churn admission is
        // admit_scenario's job, asserted elsewhere).
        assert!(
            spec::admit_staleness(algo, &StalenessSpec::SYNC, &churn).is_ok(),
            "sync+churn staleness gate for {name}"
        );
        assert_eq!(
            spec::admit_staleness(algo, &bounded, &static_sc).is_ok(),
            is_safe,
            "bounded admission for {name}"
        );
        if is_safe {
            // Bounded + per-link drops is admitted; bounded + churn is not.
            assert!(spec::admit_staleness(algo, &bounded, &drops).is_ok(), "{name}");
            let err = spec::admit_staleness(algo, &bounded, &churn).unwrap_err().to_string();
            assert!(err.contains("churn"), "{name}: '{err}'");
        } else {
            let err = spec::admit_staleness(algo, &bounded, &static_sc)
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("choco") && err.contains(&name),
                "{name}: error must name the algorithm and the safe set: '{err}'"
            );
        }
    }
}

#[test]
fn registry_self_check_constructs_every_entry_on_sim() {
    // Same check `decomp list` and the CI smoke step run: every registry
    // entry (plus the link-state cell) builds and steps at n=4.
    let cells = spec::registry::self_check(4).unwrap();
    assert_eq!(cells, spec::REGISTRY.len() + 1);
}

#[test]
fn unknown_algorithm_errors_list_the_registry_on_both_backends() {
    use decomp::coordinator::{run_simulated, run_threaded};
    use decomp::network::sim::SimOpts;
    let cfg = TrainConfig::default();
    let algo_cfg = cfg.build_algo_config().unwrap();
    let (models, x0) = cfg.build_models().unwrap();
    let err = run_threaded("adpsgd", &algo_cfg, models, &x0, 0.1, 2)
        .unwrap_err()
        .to_string();
    assert!(err.contains("registered") && err.contains("dpsgd"), "{err}");
    let (models, _) = cfg.build_models().unwrap();
    let err = run_simulated("adpsgd", &algo_cfg, models, &x0, 0.1, 2, SimOpts::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("registered") && err.contains("dpsgd"), "{err}");
}
