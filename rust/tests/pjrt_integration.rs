//! Integration tests over the real PJRT runtime: load the AOT artifacts,
//! execute grad_step / dcd_step / quantize from rust, and cross-check the
//! numerics against invariants the python test suite pinned.
//!
//! Skipped (with a notice) when `artifacts/` has not been built — run
//! `make artifacts` first.

use decomp::runtime::{JaxLm, PjrtEngine, TokenSampler};
use decomp::models::GradientModel;
use decomp::util::rng::Pcg64;
use std::path::Path;
use std::sync::Arc;

fn engine() -> Option<Arc<PjrtEngine>> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(PjrtEngine::load(&dir).expect("engine load")))
}

#[test]
fn engine_loads_and_reports_cpu() {
    let Some(e) = engine() else { return };
    assert!(e.platform().to_lowercase().contains("cpu") || !e.platform().is_empty());
    assert!(e.manifest.param_count > 0);
    assert_eq!(e.manifest.padded_dim % e.manifest.chunk, 0);
}

#[test]
fn grad_step_loss_near_log_vocab_at_init() {
    let Some(e) = engine() else { return };
    let params = e.manifest.load_init_params().expect("init params");
    let sampler = TokenSampler {
        vocab: e.manifest.vocab as i32,
        seq_len: e.manifest.seq_len,
        batch: e.manifest.batch,
        node: 0,
    };
    let mut rng = Pcg64::seed_from_u64(1);
    let tokens = sampler.sample(&mut rng);
    let (loss, grads) = e.grad_step(&params, &tokens).expect("grad_step");
    let expect = (e.manifest.vocab as f64).ln();
    assert!(
        (loss as f64 - expect).abs() < 1.0,
        "init loss {loss} vs ln(V) {expect}"
    );
    assert_eq!(grads.len(), e.manifest.param_count);
    let gnorm = decomp::linalg::vecops::norm2(&grads);
    assert!(gnorm.is_finite() && gnorm > 1e-4, "grad norm {gnorm}");
}

#[test]
fn grad_step_is_deterministic() {
    let Some(e) = engine() else { return };
    let params = e.manifest.load_init_params().unwrap();
    let sampler = TokenSampler {
        vocab: e.manifest.vocab as i32,
        seq_len: e.manifest.seq_len,
        batch: e.manifest.batch,
        node: 0,
    };
    let mut rng = Pcg64::seed_from_u64(2);
    let tokens = sampler.sample(&mut rng);
    let (l1, g1) = e.grad_step(&params, &tokens).unwrap();
    let (l2, g2) = e.grad_step(&params, &tokens).unwrap();
    assert_eq!(l1, l2);
    assert_eq!(g1, g2);
}

#[test]
fn sgd_on_pjrt_reduces_loss() {
    let Some(e) = engine() else { return };
    let mut params = e.manifest.load_init_params().unwrap();
    let sampler = TokenSampler {
        vocab: e.manifest.vocab as i32,
        seq_len: e.manifest.seq_len,
        batch: e.manifest.batch,
        node: 0,
    };
    let mut rng = Pcg64::seed_from_u64(3);
    // Overfit one fixed batch — a guaranteed descent direction check.
    let t0 = sampler.sample(&mut rng);
    let (l0, _) = e.grad_step(&params, &t0).unwrap();
    for _ in 0..10 {
        let (_, g) = e.grad_step(&params, &t0).unwrap();
        decomp::linalg::vecops::axpy(-0.2, &g, &mut params);
    }
    let (l1, _) = e.grad_step(&params, &t0).unwrap();
    assert!(l1 < l0 - 0.1, "loss should drop: {l0} -> {l1}");
}

#[test]
fn quantize_artifact_matches_rust_dequant_contract() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let mut rng = Pcg64::seed_from_u64(4);
    let mut z = vec![0.0f32; m.padded_dim];
    rng.fill_normal_f32(&mut z, 0.0, 0.1);
    let (levels, scales) = e.quantize(&z, 42).expect("quantize");
    assert_eq!(levels.len(), m.padded_dim);
    assert_eq!(scales.len(), m.nchunks);
    let lm1 = ((1u32 << m.bits) - 1) as f32;
    assert!(levels.iter().all(|&q| (0.0..=lm1).contains(&q) && q.fract() == 0.0));
    // Dequantize on the rust side: error bounded by one step per chunk.
    let mut out = vec![0.0f32; m.padded_dim];
    e.dequantize_levels(&levels, &scales, &mut out);
    for (ci, chunk) in z.chunks(m.chunk).enumerate() {
        let step = 2.0 * scales[ci] / lm1;
        for (a, b) in chunk.iter().zip(&out[ci * m.chunk..]) {
            assert!((a - b).abs() <= step + 1e-5, "{a} vs {b} (step {step})");
        }
    }
}

#[test]
fn gossip_artifact_matches_rust_vecops() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let n = m.padded_dim;
    let mut rng = Pcg64::seed_from_u64(5);
    let mut x = vec![0.0f32; n];
    let mut nbrs = vec![0.0f32; m.degree * n];
    let mut grad = vec![0.0f32; n];
    rng.fill_normal_f32(&mut x, 0.0, 1.0);
    rng.fill_normal_f32(&mut nbrs, 0.0, 1.0);
    rng.fill_normal_f32(&mut grad, 0.0, 1.0);
    let w = vec![1.0 / 3.0; m.degree + 1];
    let gamma = 0.1f32;
    let out = e.gossip(&x, &nbrs, &w, gamma, &grad).expect("gossip");
    // Rust reference.
    let mut expect = vec![0.0f32; n];
    let mut cols: Vec<&[f32]> = vec![&x];
    for d in 0..m.degree {
        cols.push(&nbrs[d * n..(d + 1) * n]);
    }
    decomp::linalg::vecops::weighted_sum(&w, &cols, &mut expect);
    decomp::linalg::vecops::axpy(-gamma, &grad, &mut expect);
    for (a, b) in out.iter().zip(&expect) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn fused_dcd_step_consistent_with_parts() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let n = m.padded_dim;
    let params = e.manifest.load_init_params().unwrap();
    let mut x = vec![0.0f32; n];
    x[..m.param_count].copy_from_slice(&params);
    // Neighbors = x plus small perturbations.
    let mut rng = Pcg64::seed_from_u64(6);
    let mut nbrs = vec![0.0f32; m.degree * n];
    for d in 0..m.degree {
        let row = &mut nbrs[d * n..(d + 1) * n];
        row.copy_from_slice(&x);
        for v in row.iter_mut() {
            *v += rng.normal_with(0.0, 1e-3) as f32;
        }
    }
    let w = vec![1.0 / 3.0; m.degree + 1];
    let sampler = TokenSampler {
        vocab: m.vocab as i32,
        seq_len: m.seq_len,
        batch: m.batch,
        node: 0,
    };
    let tokens = sampler.sample(&mut rng);
    let out = e
        .dcd_step(&x, &nbrs, &w, 0.1, &tokens, 7)
        .expect("dcd_step");
    assert!(out.loss.is_finite());
    assert_eq!(out.x_new.len(), n);
    assert_eq!(out.levels.len(), n);
    assert_eq!(out.scales.len(), m.nchunks);
    // x_new = x + dequant(levels, scales) — exactly (kernel semantics).
    let mut cz = vec![0.0f32; n];
    e.dequantize_levels(&out.levels, &out.scales, &mut cz);
    for i in 0..n {
        let expect = x[i] + cz[i];
        assert!(
            (out.x_new[i] - expect).abs() < 1e-5,
            "i={i}: {} vs {expect}",
            out.x_new[i]
        );
    }
}

#[test]
fn jaxlm_gradient_model_contract() {
    let Some(e) = engine() else { return };
    let mut lm = JaxLm::new(e.clone(), 0, 0xee);
    assert_eq!(lm.dim(), e.manifest.param_count);
    let params = e.manifest.load_init_params().unwrap();
    let mut g = vec![0.0f32; lm.dim()];
    let mut rng = Pcg64::seed_from_u64(7);
    let loss = lm.stoch_grad(&params, &mut g, &mut rng);
    assert!(loss.is_finite() && loss > 0.0);
    let full = lm.full_loss(&params);
    assert!(full.is_finite() && full > 0.0);
    // full_loss is deterministic.
    assert_eq!(full, lm.full_loss(&params));
}
