//! The streaming results plane, end to end: the pull parser and push
//! writer must agree with the retired tree path on the repo's real
//! artifacts (`BENCH_baseline.json`), counters must survive the
//! full write→parse cycle exactly, and no experiment driver may build
//! `Json` trees for output again (grep-pinned).

use decomp::algorithms::{TracePoint, TrainTrace};
use decomp::bench_harness::summary::BenchReport;
use decomp::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

fn baseline_text() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_baseline.json");
    std::fs::read_to_string(&path).expect("checked-in BENCH_baseline.json")
}

/// The report as the old tree emitter would have built it: one
/// `Json::Obj` whose BTreeMap ordering produced alphabetical keys.
fn report_tree(r: &BenchReport) -> Json {
    let groups = r
        .groups
        .iter()
        .map(|(g, ms)| {
            let metrics = ms
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v)))
                .collect::<BTreeMap<_, _>>();
            (g.clone(), Json::Obj(metrics))
        })
        .collect::<BTreeMap<_, _>>();
    Json::obj(vec![
        ("groups", Json::Obj(groups)),
        ("quick", Json::Bool(r.quick)),
        ("schema", Json::Str("decomp-bench-v1".to_string())),
    ])
}

#[test]
fn bench_report_streaming_emission_matches_tree_emitter() {
    // BENCH_pr.json must not change bytes because the emitter became
    // streaming: write_json == the tree emission of the same report
    // (properties.rs pins tree emission == the retired recursive
    // emitter on the full grammar).
    let report = BenchReport::parse(&baseline_text()).unwrap();
    let mut streamed = Vec::new();
    report.write_json(&mut streamed).unwrap();
    let streamed = String::from_utf8(streamed).unwrap();
    assert_eq!(streamed, report_tree(&report).to_pretty());
    assert!(streamed.starts_with("{\n  \"groups\": {\n"), "{streamed:.60}");
    assert!(streamed.ends_with("}\n"));
    // And the streamed document parses back to the same report.
    let reparsed = BenchReport::parse(&streamed).unwrap();
    assert_eq!(reparsed.quick, report.quick);
    assert_eq!(reparsed.groups, report.groups);
}

#[test]
fn bench_baseline_pull_parse_equivalent_to_tree_parse() {
    // The pull parser must extract exactly what a tree walk over
    // `Json::parse` extracts — including dropping `null` placeholder
    // metrics (host-dependent entries the baseline ships unrecorded).
    let text = baseline_text();
    let pulled = BenchReport::parse(&text).unwrap();
    let tree = Json::parse(&text).unwrap();
    assert_eq!(
        Some(pulled.quick),
        tree.get("quick").and_then(|q| q.as_bool())
    );
    let tree_groups: BTreeMap<String, BTreeMap<String, f64>> = tree
        .get("groups")
        .and_then(|g| g.as_obj())
        .expect("baseline has groups")
        .iter()
        .map(|(g, ms)| {
            let metrics = ms
                .as_obj()
                .expect("group is an object")
                .iter()
                .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                .collect();
            (g.clone(), metrics)
        })
        .collect();
    assert_eq!(pulled.groups, tree_groups);
    // The baseline really does exercise the null-skipping path.
    assert!(
        pulled.groups["host_sweep_wall_s"].is_empty(),
        "expected the baseline's null wall-clock metrics to be dropped"
    );
}

#[test]
fn trace_counters_above_2_pow_53_round_trip_exactly() {
    // Json::Num(f64) loses u64 precision above 2^53; the streaming
    // writer's num_u64 path must not. 2^60 + 3 is unrepresentable in
    // f64 (rounds to 2^60), so a lossy path cannot pass this test.
    let big = (1u64 << 60) + 3;
    let trace = TrainTrace {
        algo: "counters".to_string(),
        points: vec![TracePoint {
            iter: (1 << 54) + 1,
            global_loss: 0.25,
            consensus: 0.5,
            bytes_sent: big,
            sim_time_s: 1.5,
        }],
    };
    for pretty in [false, true] {
        let mut buf = Vec::new();
        trace.write_json(&mut buf, pretty).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains(&big.to_string()), "{text}");
        let back = TrainTrace::parse(&text).unwrap();
        assert_eq!(back.points[0].bytes_sent, big);
        assert_eq!(back.points[0].iter, (1 << 54) + 1);
    }
}

#[test]
fn no_experiments_file_builds_json_trees_for_output() {
    // The API-redesign pin: every experiment driver emits through
    // Table + Sink (streaming); constructing `Json::Obj`/`Json::obj(`
    // in experiments/ would reopen the tree-emission path this PR
    // closed.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src/experiments");
    let mut checked = 0;
    for entry in std::fs::read_dir(&dir).expect("experiments dir") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("rs") {
            continue;
        }
        let src = std::fs::read_to_string(&path).unwrap();
        for needle in ["Json::Obj", "Json::obj(", "to_pretty()", ".to_json("] {
            assert!(
                !src.contains(needle),
                "{} constructs a JSON tree for output ({needle}); \
                 emit through JsonWriter/Sink instead",
                path.display()
            );
        }
        checked += 1;
    }
    assert!(checked >= 9, "expected to scan the experiment drivers, saw {checked}");
}
