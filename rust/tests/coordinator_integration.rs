//! Threaded coordinator ≡ single-process simulator, bitwise.
//!
//! The strongest correctness statement in the repo: for every algorithm,
//! running n worker *threads* exchanging real serialized wire messages
//! produces exactly the same trajectory as the deterministic simulator,
//! given the same seed. Any divergence in RNG stream layout, operation
//! order, or wire round-tripping breaks these tests.

use decomp::algorithms::{self, AlgoConfig, Algorithm};
use decomp::compression::{self};
use decomp::coordinator::{run_threaded, TrainConfig};
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::models::GradientModel;
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::sync::Arc;

fn setup(
    n: usize,
    dim: usize,
    compressor: &str,
    seed: u64,
) -> (
    AlgoConfig,
    Vec<Box<dyn GradientModel>>,
    Vec<Box<dyn GradientModel>>,
    Vec<f32>,
) {
    let spec = SynthSpec {
        n_nodes: n,
        rows_per_node: 64,
        dim,
        noise: 0.1,
        heterogeneity: 0.5,
        seed: 0xabc,
    };
    let kind = ModelKind::Linear { batch: 4 };
    let (m1, x0) = build_models(&kind, &spec);
    let (m2, _) = build_models(&kind, &spec);
    let (comp, link) = compression::resolve_name(compressor).unwrap();
    let cfg = AlgoConfig {
        mixing: Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n))),
        compressor: comp,
        seed,
        eta: 1.0,
        link,
        scenario: None,
    };
    (cfg, m1, m2, x0)
}

fn clone_cfg(cfg: &AlgoConfig) -> AlgoConfig {
    AlgoConfig {
        mixing: cfg.mixing.clone(),
        compressor: cfg.compressor.clone(),
        seed: cfg.seed,
        eta: cfg.eta,
        link: cfg.link.clone(),
        scenario: cfg.scenario.clone(),
    }
}

fn assert_bitwise(algo_name: &str, compressor: &str) {
    let n = 6;
    let dim = 48;
    let iters = 40;
    let gamma = 0.05;
    let (mut cfg, mut m_sim, m_thr, x0) = setup(n, dim, compressor, 42);
    // Exercise the η ≠ 1 path for the error-feedback family.
    if matches!(algo_name, "choco" | "deepsqueeze") {
        cfg.eta = 0.4;
    }

    let mut sim = algorithms::from_name(algo_name, clone_cfg(&cfg), &x0, n).unwrap();
    for _ in 0..iters {
        sim.step(&mut m_sim, gamma);
    }

    let run = run_threaded(algo_name, &cfg, m_thr, &x0, gamma, iters).unwrap();
    let threaded = run.final_params();

    for (i, (a, b)) in sim.params().iter().zip(&threaded).enumerate() {
        for (d, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{algo_name}/{compressor}: node {i} dim {d}: sim {x} vs threaded {y}"
            );
        }
    }
}

#[test]
fn dpsgd_threaded_bitwise_equals_simulator() {
    assert_bitwise("dpsgd", "fp32");
}

#[test]
fn dcd_threaded_bitwise_equals_simulator() {
    assert_bitwise("dcd", "q8");
}

#[test]
fn dcd_4bit_threaded_bitwise_equals_simulator() {
    assert_bitwise("dcd", "q4");
}

#[test]
fn ecd_threaded_bitwise_equals_simulator() {
    assert_bitwise("ecd", "q8");
}

#[test]
fn naive_threaded_bitwise_equals_simulator() {
    assert_bitwise("naive", "q8");
}

#[test]
fn allreduce_threaded_bitwise_equals_simulator() {
    assert_bitwise("allreduce", "fp32");
}

#[test]
fn qallreduce_threaded_bitwise_equals_simulator() {
    assert_bitwise("qallreduce", "q8");
}

#[test]
fn choco_threaded_bitwise_equals_simulator() {
    assert_bitwise("choco", "q8");
}

#[test]
fn choco_sign_threaded_bitwise_equals_simulator() {
    assert_bitwise("choco", "sign");
}

#[test]
fn deepsqueeze_threaded_bitwise_equals_simulator() {
    assert_bitwise("deepsqueeze", "q4");
}

#[test]
fn deepsqueeze_topk_threaded_bitwise_equals_simulator() {
    assert_bitwise("deepsqueeze", "topk_25");
}

#[test]
fn choco_lowrank_threaded_bitwise_equals_simulator() {
    // The link-state family closes the triangle: reference ≡ threads
    // (backend_equivalence pins threads ≡ sim), warm-started per-link
    // power-iteration state included.
    assert_bitwise("choco", "lowrank_r2");
    assert_bitwise("choco", "lowrank_r4");
}

#[test]
fn dcd_replicas_mirror_models() {
    // The replica invariant (§4.1 footnote 3): every neighbor's copy of a
    // node's model equals the node's actual model. Verified indirectly by
    // the bitwise tests (the threaded run keeps real, independently
    // updated replica buffers; the simulator assumes x̂ ≡ x — a broken
    // invariant splits the trajectories immediately). Here: message
    // accounting — each node sends exactly iters × degree wires.
    let n = 6;
    let (cfg, _, m_thr, x0) = setup(n, 32, "q8", 7);
    let run = run_threaded("dcd", &cfg, m_thr, &x0, 0.05, 25).unwrap();
    for r in &run.reports {
        assert_eq!(r.msgs_sent, 25 * 2, "node {}", r.node);
        assert!(r.bytes_sent > 0);
    }
}

#[test]
fn threaded_wire_sizes_reflect_compression() {
    let n = 6;
    let dim = 4096;
    let (cfg_q, _, m_q, x0) = setup(n, dim, "q8", 9);
    let (cfg_f, _, m_f, _) = setup(n, dim, "fp32", 9);
    let bytes_q = run_threaded("dcd", &cfg_q, m_q, &x0, 0.05, 10)
        .unwrap()
        .total_bytes();
    let bytes_f = run_threaded("dcd", &cfg_f, m_f, &x0, 0.05, 10)
        .unwrap()
        .total_bytes();
    let ratio = bytes_q as f64 / bytes_f as f64;
    assert!((0.2..0.3).contains(&ratio), "8-bit wire ratio {ratio}");
}

#[test]
fn threaded_training_converges() {
    // End-to-end sanity through the public TrainConfig path.
    let cfg = TrainConfig {
        algo: "dcd".into(),
        n_nodes: 8,
        iters: 300,
        gamma: 0.05,
        model: "logistic".into(),
        dim: 32,
        ..Default::default()
    };
    let algo_cfg = cfg.build_algo_config().unwrap();
    let (models, x0) = cfg.build_models().unwrap();
    let (eval_models, _) = cfg.build_models().unwrap();
    let run = run_threaded(&cfg.algo, &algo_cfg, models, &x0, cfg.gamma, cfg.iters).unwrap();
    let mean = run.mean_params();
    let init_loss: f64 = eval_models.iter().map(|m| m.full_loss(&x0)).sum::<f64>() / 8.0;
    let final_loss: f64 = eval_models.iter().map(|m| m.full_loss(&mean)).sum::<f64>() / 8.0;
    assert!(
        final_loss < 0.7 * init_loss,
        "threaded DCD should train: {init_loss} -> {final_loss}"
    );
    // Loss trace is populated and decreasing on average.
    let losses = run.mean_losses();
    assert_eq!(losses.len(), 300);
    let head: f64 = losses[..30].iter().sum::<f64>() / 30.0;
    let tail: f64 = losses[270..].iter().sum::<f64>() / 30.0;
    assert!(tail < head);
}

#[test]
fn unsupported_algorithm_rejected() {
    let (cfg, _, m, x0) = setup(4, 8, "fp32", 1);
    assert!(run_threaded("adpsgd", &cfg, m, &x0, 0.1, 5).is_err());
}
