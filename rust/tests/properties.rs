//! Property-based tests over the library's core invariants, using the
//! in-tree mini-framework (`decomp::util::prop`) — randomized inputs,
//! deterministic seeds, failure cases reported by seed.

use decomp::algorithms::{self, consensus_distance, AlgoConfig};
use decomp::compression::{
    from_name, Compressor, Identity, LinkCompressor, LinkCompressorSpec, LowRankSpec,
    RandomSparsifier, SignCompressor, StochasticQuantizer, TopK, Wire,
};
use decomp::linalg::eig::{spectral_stats, symmetric_eigen};
use decomp::linalg::mat::{orthonormalize_columns, Mat};
use decomp::linalg::vecops;
use decomp::models::{GradientModel, Quadratic, ShapeManifest, TensorShape, TensorViewMut};
use decomp::network::sim::Frame;
use decomp::network::transport::Channel;
use decomp::topology::{
    is_doubly_stochastic, masked_metropolis_rows, masked_metropolis_weights, metropolis_weights,
    uniform_neighbor_weights, Graph, MixingMatrix, Topology,
};
use decomp::util::prop::{check, Gen};
use decomp::util::rng::Pcg64;
use std::sync::Arc;

const CASES: u64 = 40;

fn random_topology(g: &mut Gen) -> (Topology, usize) {
    match g.usize_in(0, 5) {
        0 => (Topology::Ring, g.usize_in(3, 20)),
        1 => (Topology::FullyConnected, g.usize_in(2, 12)),
        2 => (Topology::Chain, g.usize_in(2, 16)),
        3 => (Topology::Star, g.usize_in(3, 16)),
        4 => {
            let r = g.usize_in(3, 4);
            let c = g.usize_in(3, 4);
            (Topology::Torus2d { rows: r, cols: c }, r * c)
        }
        _ => (
            Topology::Random {
                p_percent: g.usize_in(20, 80) as u8,
                seed: g.rng.next_u64(),
            },
            g.usize_in(4, 14),
        ),
    }
}

fn build_mixing(topo: Topology, n: usize) -> MixingMatrix {
    let graph = Graph::build(topo, n);
    let d0 = graph.degree(0);
    let regular = (0..graph.n).all(|i| graph.degree(i) == d0);
    if regular {
        MixingMatrix::uniform(graph)
    } else {
        MixingMatrix::metropolis(graph)
    }
}

#[test]
fn prop_graphs_connected_and_symmetric() {
    check("graphs connected+symmetric", CASES, |g| {
        let (topo, n) = random_topology(g);
        let graph = Graph::build(topo, n);
        assert!(graph.is_connected());
        assert!(graph.is_valid_undirected());
        assert_eq!(graph.n, n);
    });
}

#[test]
fn prop_mixing_matrices_doubly_stochastic_with_rho_below_one() {
    check("mixing doubly stochastic, rho<1", CASES, |g| {
        let (topo, n) = random_topology(g);
        let m = build_mixing(topo, n);
        assert!(is_doubly_stochastic(m.w(), 1e-9));
        assert!(m.stats().rho < 1.0 - 1e-9, "rho {} for {:?}", m.stats().rho, topo);
        assert!(m.stats().gap > 0.0);
        assert!(m.dcd_alpha_bound() > 0.0);
    });
}

#[test]
fn prop_csr_mixing_rows_match_dense_oracle_bitwise() {
    // The sparse CSR rows the n=16384 engine mixes with must be *the
    // same numbers* the dense small-n oracle holds — bitwise, including
    // under masked-Metropolis churn masks — across the topology families
    // the scaling sweeps use, up past the point where the cached oracle
    // exists for cross-checking at runtime.
    check("CSR mixing rows == dense oracle, bitwise", CASES, |g| {
        let n = *g.choose(&[4usize, 64, 128]);
        let topo = match g.usize_in(0, 3) {
            0 => Topology::Ring,
            1 => Topology::Hypercube,
            2 => Topology::Random {
                p_percent: g.usize_in(15, 60) as u8,
                seed: g.rng.next_u64(),
            },
            // No 2-D torus exists at n = 4 (needs r,c ≥ 3).
            _ if n == 4 => Topology::Ring,
            _ => Topology::Torus2d { rows: 8, cols: n / 8 },
        };
        let graph = Graph::build(topo, n);
        let d0 = graph.degree(0);
        let regular = (0..n).all(|i| graph.degree(i) == d0);
        let (m, w) = if regular {
            (MixingMatrix::uniform(graph.clone()), uniform_neighbor_weights(&graph))
        } else {
            (MixingMatrix::metropolis(graph.clone()), metropolis_weights(&graph))
        };
        for i in 0..n {
            assert_eq!(
                m.self_weight[i].to_bits(),
                (w[(i, i)] as f32).to_bits(),
                "diagonal at node {i} ({topo:?})"
            );
            let row = m.neighbor_weights(i);
            assert_eq!(row.len(), graph.neighbors[i].len());
            for (k, &j) in graph.neighbors[i].iter().enumerate() {
                assert_eq!(
                    row[k].to_bits(),
                    (w[(i, j)] as f32).to_bits(),
                    "edge {i}->{j} ({topo:?})"
                );
            }
        }
        // Same pin for the churn-masked Metropolis rows: freeze a random
        // subset and compare against the dense masked oracle. A mask that
        // strands a live node must be refused by both paths.
        let mut live = vec![true; n];
        for v in live.iter_mut() {
            if g.f64_in(0.0, 1.0) < 0.2 {
                *v = false;
            }
        }
        match masked_metropolis_rows(&graph, &live) {
            Ok(rows) => {
                let wm = masked_metropolis_weights(&graph, &live)
                    .expect("oracle accepts what the sparse path accepts");
                for i in 0..n {
                    assert_eq!(
                        rows.self_weight[i].to_bits(),
                        (wm[(i, i)] as f32).to_bits(),
                        "masked diagonal at node {i} ({topo:?})"
                    );
                    for (k, &j) in graph.neighbors[i].iter().enumerate() {
                        assert_eq!(
                            rows.neighbor_weights(i)[k].to_bits(),
                            (wm[(i, j)] as f32).to_bits(),
                            "masked edge {i}->{j} ({topo:?})"
                        );
                    }
                }
            }
            Err(_) => {
                assert!(
                    masked_metropolis_weights(&graph, &live).is_err(),
                    "sparse path refused a mask the dense oracle accepts ({topo:?})"
                );
            }
        }
    });
}

#[test]
fn prop_eigensolver_reconstructs_matrix() {
    check("eigensolver A = V Λ V^T", CASES, |g| {
        let n = g.usize_in(2, 8);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let v = g.f64_in(-2.0, 2.0);
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let e = symmetric_eigen(&a);
        let mut lam = Mat::zeros(n, n);
        for i in 0..n {
            lam[(i, i)] = e.values[i];
        }
        let rebuilt = e.vectors.matmul(&lam).matmul(&e.vectors.transpose());
        assert!(
            rebuilt.max_abs_diff(&a) < 1e-8,
            "reconstruction error {}",
            rebuilt.max_abs_diff(&a)
        );
    });
}

#[test]
fn prop_spectral_stats_bounded() {
    check("spectral invariants of doubly stochastic W", CASES, |g| {
        let (topo, n) = random_topology(g);
        let graph = Graph::build(topo, n);
        let d0 = graph.degree(0);
        let regular = (0..graph.n).all(|i| graph.degree(i) == d0);
        let w = if regular {
            decomp::topology::uniform_neighbor_weights(&graph)
        } else {
            decomp::topology::metropolis_weights(&graph)
        };
        let s = spectral_stats(&w);
        // Eigenvalues of a symmetric doubly stochastic matrix lie in
        // [-1, 1] with λ₁ = 1; µ = max |λᵢ − 1| ≤ 2.
        assert!(s.lambda2 <= 1.0 + 1e-9);
        assert!(s.lambda_n >= -1.0 - 1e-9);
        assert!(s.mu <= 2.0 + 1e-9);
        assert!((0.0..=1.0 + 1e-9).contains(&s.rho));
    });
}

#[test]
fn prop_quantizer_roundtrip_error_bounded() {
    check("quantizer |C(z)-z| <= step", CASES, |g| {
        let bits = *g.choose(&[1u8, 2, 3, 4, 6, 8]);
        let chunk = *g.choose(&[64usize, 256, 1024]);
        let q = StochasticQuantizer::with_chunk(bits, chunk);
        let scale_mag = g.f32_in(0.01, 100.0);
        let z = g.vec_f32(1, 3000, scale_mag);
        let mut out = vec![0.0f32; z.len()];
        q.apply(&z, &mut g.rng.split(7), &mut out);
        let lm1 = ((1u32 << bits) - 1) as f64;
        for (ci, c) in z.chunks(chunk).enumerate() {
            let scale = vecops::max_abs(c) as f64;
            let step = 2.0 * scale / lm1;
            for (a, b) in c.iter().zip(&out[ci * chunk..]) {
                assert!(
                    ((a - b).abs() as f64) <= step + 1e-4 * scale.max(1.0),
                    "bits={bits} chunk={chunk}: |{a}-{b}| > {step}"
                );
            }
        }
    });
}

#[test]
fn prop_wire_bytes_matches_actual_payload() {
    check("wire_bytes accounting exact for deterministic codecs", CASES, |g| {
        let z = g.vec_f32(1, 5000, 1.0);
        let mut rng = g.rng.split(3);
        for name in ["fp32", "q8", "q4", "q1", "topk_10", "sign"] {
            let c = from_name(name).unwrap();
            let w = c.compress(&z, &mut rng);
            assert_eq!(w.bytes(), c.wire_bytes(z.len()), "{name} at n={}", z.len());
        }
        // Sparsifier is stochastic: expected size within 30% for n ≥ 500.
        if z.len() >= 500 {
            let s = RandomSparsifier::new(0.25);
            let w = s.compress(&z, &mut rng);
            let expect = s.wire_bytes(z.len()) as f64;
            assert!(
                (w.bytes() as f64 - expect).abs() < 0.3 * expect,
                "sparse: {} vs {expect}",
                w.bytes()
            );
        }
    });
}

#[test]
fn prop_identity_bitwise_roundtrip() {
    check("identity codec roundtrips bitwise incl. specials", CASES, |g| {
        let mut z = g.vec_f32(1, 200, 1e20);
        let n = z.len();
        z[0] = 0.0;
        if n > 1 {
            z[n / 2] = f32::MIN_POSITIVE;
        }
        let w = Identity.compress(&z, &mut g.rng.split(1));
        let mut out = vec![0.0f32; z.len()];
        Identity.decompress(&w, &mut out);
        for (a, b) in z.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    });
}

#[test]
fn prop_gossip_preserves_mean_any_topology() {
    check("gossip preserves the mean (1ᵀW = 1ᵀ)", CASES, |g| {
        let (topo, n) = random_topology(g);
        let mixing = Arc::new(build_mixing(topo, n));
        let dim = g.usize_in(1, 32);
        let fam: Vec<Quadratic> = (0..n)
            .map(|_| Quadratic::new(g.vec_f32(dim, dim, 1.0), 0.0))
            .collect();
        let mut models: Vec<Box<dyn GradientModel>> = fam
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradientModel>)
            .collect();
        let x0 = g.vec_f32(dim, dim, 1.0);
        let cfg = AlgoConfig {
            mixing,
            compressor: Arc::new(Identity),
            seed: g.rng.next_u64(),
            eta: 1.0,
            link: None,
            scenario: None,
        };
        let mut a = algorithms::from_name("dpsgd", cfg, &x0, n).unwrap();
        let mut mean_before = vec![0.0f32; dim];
        a.mean_params(&mut mean_before);
        // γ=0 steps are pure gossip — the mean is invariant (1ᵀW = 1ᵀ).
        for _ in 0..3 {
            a.step(&mut models, 0.0);
        }
        let mut mean_after = vec![0.0f32; dim];
        a.mean_params(&mut mean_after);
        for (x, y) in mean_before.iter().zip(&mean_after) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    });
}

#[test]
fn prop_pure_gossip_contracts_consensus() {
    check("repeated mixing contracts consensus distance", CASES / 2, |g| {
        let (topo, n) = random_topology(g);
        if n < 3 {
            return;
        }
        let mixing = Arc::new(build_mixing(topo, n));
        let dim = 8;
        let zero_fam: Vec<Quadratic> =
            (0..n).map(|_| Quadratic::new(vec![0.0; dim], 0.0)).collect();
        let mut models: Vec<Box<dyn GradientModel>> = zero_fam
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradientModel>)
            .collect();
        let cfg = AlgoConfig {
            mixing,
            compressor: Arc::new(Identity),
            seed: 1,
            eta: 1.0,
            link: None,
            scenario: None,
        };
        let x0 = vec![0.0f32; dim];
        let mut a = algorithms::from_name("dpsgd", cfg, &x0, n).unwrap();
        // Kick nodes apart: one step toward distinct random centers.
        let fam2: Vec<Quadratic> = (0..n)
            .map(|_| Quadratic::new(g.vec_f32(dim, dim, 5.0), 0.0))
            .collect();
        let mut kick: Vec<Box<dyn GradientModel>> = fam2
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradientModel>)
            .collect();
        a.step(&mut kick, 1.0);
        let mut prev = consensus_distance(a.params());
        for _ in 0..5 {
            a.step(&mut models, 0.0);
            let cur = consensus_distance(a.params());
            assert!(cur <= prev * (1.0 + 1e-5) + 1e-12, "{cur} > {prev}");
            prev = cur;
        }
    });
}

#[test]
fn prop_dcd_fp32_equals_dpsgd_all_topologies() {
    check("DCD with identity codec ≡ D-PSGD", CASES / 2, |g| {
        let (topo, n) = random_topology(g);
        let mixing = Arc::new(build_mixing(topo, n));
        let dim = g.usize_in(2, 24);
        let seed = g.rng.next_u64();
        let mk_models = |s: u64| -> Vec<Box<dyn GradientModel>> {
            (0..n)
                .map(|i| {
                    let mut r = Pcg64::new(s, i as u64);
                    let mut c = vec![0.0f32; dim];
                    r.fill_normal_f32(&mut c, 0.0, 1.0);
                    Box::new(Quadratic::new(c, 0.2)) as Box<dyn GradientModel>
                })
                .collect()
        };
        let x0 = vec![0.0f32; dim];
        let mk_cfg = || AlgoConfig {
            mixing: mixing.clone(),
            compressor: Arc::new(Identity),
            seed,
            eta: 1.0,
            link: None,
            scenario: None,
        };
        let mut dcd = algorithms::from_name("dcd", mk_cfg(), &x0, n).unwrap();
        let mut dp = algorithms::from_name("dpsgd", mk_cfg(), &x0, n).unwrap();
        let mut m1 = mk_models(seed ^ 1);
        let mut m2 = mk_models(seed ^ 1);
        for _ in 0..10 {
            dcd.step(&mut m1, 0.1);
            dp.step(&mut m2, 0.1);
        }
        for (a, b) in dcd.params().iter().zip(dp.params()) {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    });
}

#[test]
fn prop_bitpack_roundtrip_random_widths() {
    check("bit packer roundtrips random streams", CASES, |g| {
        use decomp::compression::{BitReader, BitWriter};
        let count = g.usize_in(1, 500);
        let mut widths = Vec::with_capacity(count);
        let mut values = Vec::with_capacity(count);
        let mut w = BitWriter::new();
        for _ in 0..count {
            let width = g.usize_in(1, 32) as u32;
            let max = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
            let v = (g.rng.next_u64() as u32) & max;
            w.push(v, width);
            widths.push(width);
            values.push(v);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for (width, v) in widths.iter().zip(&values) {
            assert_eq!(r.read(*width), *v);
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    check("json parse(to_string(v)) == v", CASES, |g| {
        use decomp::util::json::Json;
        fn random_json(g: &mut Gen, depth: usize) -> Json {
            match if depth > 2 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}_\"q\"\n", g.usize_in(0, 999))),
                4 => Json::Arr(
                    (0..g.usize_in(0, 4))
                        .map(|_| random_json(g, depth + 1))
                        .collect(),
                ),
                _ => Json::Obj(
                    (0..g.usize_in(0, 4))
                        .map(|i| (format!("k{i}"), random_json(g, depth + 1)))
                        .collect(),
                ),
            }
        }
        let v = random_json(g, 0);
        let s = v.to_string();
        let parsed = Json::parse(&s).unwrap_or_else(|e| panic!("parse '{s}': {e}"));
        assert_eq!(parsed, v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    });
}

#[test]
fn prop_sign_wire_round_trip_exact() {
    check("sign wire round-trips to ±(‖z‖₁/d) with matching signs", CASES, |g| {
        let z = g.vec_f32(1, 3000, g.f32_in(0.01, 100.0));
        let c = SignCompressor;
        let w = c.compress(&z, &mut g.rng.split(2));
        assert_eq!(w.bytes(), c.wire_bytes(z.len()), "honest 1-bit wire size");
        let mut out = vec![0.0f32; z.len()];
        c.decompress(&w, &mut out);
        // Recompute the scale exactly as the codec defines it.
        let l1: f64 = z.iter().map(|v| v.abs() as f64).sum();
        let scale = (l1 / z.len() as f64) as f32;
        for (i, (zi, oi)) in z.iter().zip(&out).enumerate() {
            let expect = if *zi >= 0.0 { scale } else { -scale };
            assert_eq!(oi.to_bits(), expect.to_bits(), "index {i}: {oi} vs {expect}");
        }
    });
}

#[test]
fn prop_biased_compressors_are_contractions() {
    // The error-feedback admissibility condition: ‖z − C(z)‖² ≤ (1−δ)‖z‖²
    // with δ = k/d for top-k (exact: the dropped mass is the smallest
    // d−k squares) and δ = ‖z‖₁²/(d‖z‖²) for sign (exact identity).
    check("top-k and sign are δ-contractions", CASES, |g| {
        let z = g.vec_f32(8, 2000, 1.0);
        let d = z.len();
        let n2 = vecops::norm2(&z).powi(2);
        if n2 == 0.0 {
            return;
        }
        let mut out = vec![0.0f32; d];

        let frac = *g.choose(&[0.1f64, 0.25, 0.5]);
        let topk = TopK::new(frac);
        topk.apply(&z, &mut g.rng.split(4), &mut out);
        let err = vecops::dist2_sq(&z, &out);
        let k = ((d as f64 * frac).ceil() as usize).clamp(1, d);
        assert!(
            err <= (1.0 - k as f64 / d as f64) * n2 + 1e-6,
            "top-k: ‖z−C(z)‖²={err} vs (1−k/d)‖z‖²={}",
            (1.0 - k as f64 / d as f64) * n2
        );

        SignCompressor.apply(&z, &mut g.rng.split(5), &mut out);
        let err = vecops::dist2_sq(&z, &out);
        let l1: f64 = z.iter().map(|v| v.abs() as f64).sum();
        let expect = n2 - l1 * l1 / d as f64;
        assert!(
            (err - expect).abs() < 1e-3 * n2 + 1e-6,
            "sign identity: {err} vs {expect}"
        );
        assert!(err < n2, "sign must strictly contract");
    });
}

#[test]
fn prop_error_feedback_residual_decays() {
    // The EF recursion e ← (z + e) − C(z + e). Under a δ-contraction the
    // residual stays bounded while z flows, and once z stops (z = 0) it
    // drains: top-k zeroes k coordinates per step (gone in ≤ ⌈d/k⌉+1
    // steps, exactly), sign contracts ‖e‖² by ‖e‖₁²/d ≥ ‖e‖²/d per step.
    check("EF residual bounded while driven, decays when undriven", CASES / 2, |g| {
        let d = g.usize_in(16, 256);
        let z = {
            let mut v = vec![0.0f32; d];
            g.rng.fill_normal_f32(&mut v, 0.0, 1.0);
            v
        };
        let z_norm = vecops::norm2(&z);
        let mut rng = g.rng.split(6);

        // Top-k, keep 25%.
        let topk = TopK::new(0.25);
        let mut e = vec![0.0f32; d];
        let mut u = vec![0.0f32; d];
        let mut cu = vec![0.0f32; d];
        for _ in 0..40 {
            u.copy_from_slice(&z);
            vecops::axpy(1.0, &e, &mut u);
            topk.apply(&u, &mut rng, &mut cu);
            vecops::sub(&u, &cu, &mut e);
            // Fixpoint bound for δ = 1/4 is ≈ 6.5·‖z‖; allow slack.
            assert!(vecops::norm2(&e) <= 8.0 * z_norm + 1e-6, "EF residual blew up");
        }
        // Undriven: every nonzero coordinate is truncated exactly once.
        let k = (d as f64 * 0.25).ceil() as usize;
        for _ in 0..(d.div_ceil(k) + 1) {
            u.copy_from_slice(&e);
            topk.apply(&u, &mut rng, &mut cu);
            vecops::sub(&u, &cu, &mut e);
        }
        assert!(e.iter().all(|v| *v == 0.0), "top-k EF must drain exactly");

        // Sign: geometric-ish decay of the undriven residual.
        let mut e = z.clone();
        let e0 = vecops::norm2(&e);
        for _ in 0..400 {
            u.copy_from_slice(&e);
            SignCompressor.apply(&u, &mut rng, &mut cu);
            vecops::sub(&u, &cu, &mut e);
        }
        assert!(
            vecops::norm2(&e) < 0.9 * e0 + 1e-6,
            "sign EF residual should decay: {} vs {e0}",
            vecops::norm2(&e)
        );
    });
}

#[test]
fn prop_frame_roundtrip_multi_message_varint_boundaries() {
    // Frames whose payload lengths straddle the varint width boundaries
    // (1→2 bytes at 128, 2→3 bytes at 16384) must round-trip exactly,
    // `encoded_len` must match the materialized encoding, and strict
    // decoding must reject the frame the moment junk follows it.
    const BOUNDARY_SIZES: [usize; 8] = [0, 1, 126, 127, 128, 129, 16_383, 16_384];
    check("frame round-trips at varint boundaries", CASES, |g| {
        let nmsgs = g.usize_in(1, 4);
        let msgs: Vec<(Channel, Wire)> = (0..nmsgs)
            .map(|_| {
                let ch = if g.bool() { Channel::Gossip } else { Channel::Reduce };
                let len = *g.choose(&BOUNDARY_SIZES);
                let payload: Vec<u8> = (0..len).map(|_| g.rng.next_u64() as u8).collect();
                (ch, Wire { len, payload })
            })
            .collect();
        let frame = Frame { msgs };
        let enc = frame.encode();
        assert_eq!(enc.len(), frame.encoded_len(), "encoded_len is exact");
        let back = Frame::decode(&enc).expect("valid frame decodes");
        assert_eq!(back, frame);
        // Trailing junk: one stray byte (any value, zero included) kills it.
        let mut junked = enc.clone();
        junked.push(g.rng.next_u64() as u8);
        assert!(Frame::decode(&junked).is_none(), "trailing junk accepted");
        // Truncation of a non-empty encoding is rejected too.
        let mut cut = enc;
        cut.pop();
        if !cut.is_empty() {
            assert!(Frame::decode(&cut).is_none(), "truncated frame accepted");
        }
    });
}

#[test]
fn prop_recycled_wire_never_leaks_stale_bytes() {
    // The pooling contract: compress_into over a recycled buffer that
    // previously held a *longer* payload must produce a wire bitwise
    // identical to a fresh compress — same len, same bytes, no stale
    // tail. Same RNG stream on both sides makes stochastic codecs
    // comparable draw-for-draw.
    check("pooled wire reuse leaks nothing", CASES, |g| {
        let long = g.vec_f32(1500, 3000, 1.0);
        let short = g.vec_f32(1, 700, 1.0);
        for name in ["fp32", "q8", "q4", "q1", "sign", "topk_25", "sparse_p25"] {
            let c = from_name(name).unwrap();
            let tag = g.rng.next_u64();
            let fresh = c.compress(&short, &mut g.rng.split(tag));
            // Pollute: a recycled wire arrives still holding the longer
            // message's bytes and capacity.
            let mut recycled = c.compress(&long, &mut g.rng.split(tag ^ 1));
            c.compress_into(&short, &mut g.rng.split(tag), &mut recycled);
            assert_eq!(recycled.len, fresh.len, "{name}: element count");
            assert_eq!(recycled.payload, fresh.payload, "{name}: payload bytes");
        }
    });
}

#[test]
fn wire_bytes_honest_at_boundary_sizes() {
    // Satellite honesty bar: the sim engine's closed-form epoch-time
    // accounting silently drifts whenever `wire_bytes(n)` disagrees with
    // the encoded wire. Exact equality for every deterministic-size
    // codec — old and new — at the varint/chunk/fold boundary sizes.
    let sizes = [1usize, 7, 128, 16384];
    let mut rng = Pcg64::seed_from_u64(0xb17e);
    for &n in &sizes {
        let mut z = vec![0.0f32; n];
        Pcg64::new(1, n as u64).fill_normal_f32(&mut z, 0.0, 1.0);
        for name in ["fp32", "q8", "q4", "q2", "q1", "sign", "topk_10", "topk_25"] {
            let c = from_name(name).unwrap();
            let w = c.compress(&z, &mut rng);
            assert_eq!(w.bytes(), c.wire_bytes(n), "{name} at n={n}");
        }
        // Link-state low-rank: exact over the folded manifest at every n
        // (factors + full-precision tail).
        for rank in [1usize, 2, 4] {
            let m = ShapeManifest::folded(n);
            let spec = LowRankSpec::new(rank);
            let mut link = spec.build(0xb17e, 0, 1, &m);
            let w = link.compress(&z, &mut rng);
            assert_eq!(w.bytes(), link.wire_bytes(n), "lowrank_r{rank} at n={n}");
            assert_eq!(w.bytes(), spec.wire_bytes(&m), "lowrank_r{rank} spec at n={n}");
        }
        // RandomSparsifier's wire_bytes is an *expected* size — the keep
        // mask is stochastic, so exactness is impossible by construction;
        // hold the realized size to the expectation where n is
        // statistically stable.
        if n >= 1000 {
            let s = RandomSparsifier::new(0.25);
            let w = s.compress(&z, &mut rng);
            let expect = s.wire_bytes(n) as f64;
            assert!(
                (w.bytes() as f64 - expect).abs() < 0.15 * expect,
                "sparse_p25 at n={n}: {} vs expected {expect}",
                w.bytes()
            );
        }
    }
    // The structured MLP manifest is exact too (biases full precision).
    let m = ShapeManifest::mlp(64, 32, 4);
    let spec = LowRankSpec::new(4);
    let mut link = spec.build(1, 2, 3, &m);
    let mut z = vec![0.0f32; m.total_len()];
    Pcg64::new(2, 2).fill_normal_f32(&mut z, 0.0, 1.0);
    let w = link.compress(&z, &mut rng);
    assert_eq!(w.bytes(), spec.wire_bytes(&m));
    assert_eq!(w.bytes(), link.wire_bytes(m.total_len()));
}

#[test]
fn prop_shape_manifest_views_round_trip_zero_copy() {
    check("flatten(views(x)) == x, zero-copy", CASES, |g| {
        let nseg = g.usize_in(1, 5);
        let tensors: Vec<TensorShape> = (0..nseg)
            .map(|_| {
                if g.bool() {
                    TensorShape::Matrix {
                        rows: g.usize_in(1, 12),
                        cols: g.usize_in(1, 12),
                    }
                } else {
                    TensorShape::Vector { len: g.usize_in(1, 40) }
                }
            })
            .collect();
        let m = ShapeManifest { tensors };
        let len = m.total_len();
        let x = g.vec_f32(len, len, 1.0);
        // Read views: each is pointer-identical to its slice of x (no
        // copies), and they cover x exactly in order.
        let mut off = 0;
        for v in m.views(&x) {
            let d = v.data();
            assert!(std::ptr::eq(d.as_ptr(), x[off..].as_ptr()), "views must be zero-copy");
            off += d.len();
        }
        assert_eq!(off, x.len(), "views must cover the vector exactly");
        // Mutable views are disjoint and write through in layout order.
        let mut y = vec![f32::NAN; len];
        for (i, v) in m.views_mut(&mut y).into_iter().enumerate() {
            match v {
                TensorViewMut::Matrix { data, .. } | TensorViewMut::Vector { data } => {
                    data.fill(i as f32);
                }
            }
        }
        let mut off = 0;
        for (i, t) in m.tensors.iter().enumerate() {
            assert!(y[off..off + t.len()].iter().all(|v| *v == i as f32));
            off += t.len();
        }
    });
}

#[test]
fn prop_orthonormalize_columns_idempotent_at_f32_boundaries() {
    check("f32 MGS: orthonormal output, idempotent re-run", CASES, |g| {
        let nrows = g.usize_in(1, 24);
        let ncols = g.usize_in(1, nrows);
        let mut a = g.vec_f32(nrows * ncols, nrows * ncols, 1.0);
        orthonormalize_columns(&mut a, nrows);
        for k in 0..ncols {
            for j in 0..=k {
                let ck = &a[k * nrows..(k + 1) * nrows];
                let cj = &a[j * nrows..(j + 1) * nrows];
                if vecops::norm2(ck) == 0.0 || vecops::norm2(cj) == 0.0 {
                    continue; // degenerate columns are zeroed by contract
                }
                let d = vecops::dot(ck, cj);
                let expect = if j == k { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-4, "cols ({j},{k}): {d}");
            }
        }
        // Idempotence: a second pass is a no-op at f32 resolution.
        let mut b = a.clone();
        orthonormalize_columns(&mut b, nrows);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= 1e-5 * (1.0 + x.abs()), "{x} vs {y}");
        }
    });
}

#[test]
fn prop_lowrank_is_an_orthogonal_projection_contraction() {
    // The EF-admissibility condition for the PowerGossip codec:
    // M̂ = P̂P̂ᵀM, so ‖z − C(z)‖² + ‖C(z)‖² = ‖z‖² (up to f32) and in
    // particular ‖z − C(z)‖ ≤ ‖z‖ — on every warm-started round.
    check("lowrank contracts, Pythagoras holds", CASES / 2, |g| {
        let len = g.usize_in(8, 2000);
        let rank = g.usize_in(1, 6);
        let m = ShapeManifest::folded(len);
        let mut link = LowRankSpec::new(rank).build(g.rng.next_u64(), 0, 1, &m);
        let z = g.vec_f32(len, len, 1.0);
        let n2 = vecops::dot(&z, &z);
        if n2 == 0.0 {
            return;
        }
        let mut out = vec![0.0f32; len];
        for round in 0..3u64 {
            let w = link.compress(&z, &mut g.rng.split(round));
            assert_eq!(w.bytes(), link.wire_bytes(len));
            link.decompress(&w, &mut out);
            let c2 = vecops::dot(&out, &out);
            let e2 = vecops::dist2_sq(&z, &out);
            assert!(e2 <= n2 * (1.0 + 1e-3) + 1e-6, "round {round}: ‖z−C(z)‖²={e2} > ‖z‖²={n2}");
            assert!(
                (e2 + c2 - n2).abs() <= 1e-3 * n2 + 1e-6,
                "round {round}: pythagoras {e2} + {c2} vs {n2}"
            );
        }
    });
}

#[test]
fn prop_lowrank_recycled_wire_reuse_leaks_nothing() {
    // The pooling contract for the link family: compress_into over a
    // recycled buffer that previously held a longer payload must be
    // bitwise identical to a fresh compress from an identically-keyed
    // link (state evolution included).
    check("lowrank pooled wire reuse leaks nothing", CASES / 2, |g| {
        let long = g.vec_f32(1500, 3000, 1.0);
        let short = g.vec_f32(64, 700, 1.0);
        let rank = g.usize_in(1, 4);
        let seed = g.rng.next_u64();
        let mshort = ShapeManifest::folded(short.len());
        let mlong = ShapeManifest::folded(long.len());
        let mut fresh_link = LowRankSpec::new(rank).build(seed, 0, 1, &mshort);
        let fresh = fresh_link.compress(&short, &mut g.rng.split(2));
        // Pollute: a recycled wire arrives still holding a longer
        // message's bytes and capacity.
        let mut long_link = LowRankSpec::new(rank).build(seed, 0, 2, &mlong);
        let mut recycled = long_link.compress(&long, &mut g.rng.split(3));
        let mut reused_link = LowRankSpec::new(rank).build(seed, 0, 1, &mshort);
        reused_link.compress_into(&short, &mut g.rng.split(2), &mut recycled);
        assert_eq!(recycled.len, fresh.len, "element count");
        assert_eq!(recycled.payload, fresh.payload, "payload bytes");
    });
}

#[test]
fn prop_unbiasedness_flags_partition_the_codecs() {
    check("is_unbiased partitions codecs", CASES / 4, |g| {
        let q = StochasticQuantizer::new(*g.choose(&[1u8, 4, 8]));
        let sp = RandomSparsifier::new(0.25);
        let tk = TopK::new(0.25);
        let unbiased: [&dyn Compressor; 3] = [&Identity, &q, &sp];
        for c in unbiased {
            assert!(c.is_unbiased(), "{}", c.name());
        }
        let biased: [&dyn Compressor; 2] = [&tk, &SignCompressor];
        for c in biased {
            assert!(!c.is_unbiased(), "{}", c.name());
        }
    });
}

#[test]
fn prop_masked_mixing_doubly_stochastic_under_any_churn_mask() {
    // The scenario engine's churn-window weights: Metropolis over the
    // live-induced subgraph with identity rows for dead nodes. For every
    // mask the function either rejects cleanly (a live node stranded
    // with zero live neighbors) or returns a symmetric doubly stochastic
    // matrix that never routes weight through a dead node.
    check("masked Metropolis stays doubly stochastic", CASES, |g| {
        let (topo, n) = random_topology(g);
        let graph = Graph::build(topo, n);
        let mut live = vec![true; n];
        for l in live.iter_mut() {
            // Bias toward mostly-live masks (the realistic churn regime)
            // but keep degenerate ones in the mix for the Err path.
            *l = g.usize_in(0, 4) != 0;
        }
        let Ok(w) = masked_metropolis_weights(&graph, &live) else {
            // Rejected masks must actually be degenerate.
            let stranded = (0..n).any(|i| live[i] && graph.neighbors[i].iter().all(|&j| !live[j]));
            assert!(stranded, "rejected a non-degenerate mask for {topo:?}");
            return;
        };
        assert!(is_doubly_stochastic(&w, 1e-9));
        for i in 0..n {
            for j in 0..n {
                assert!((w[(i, j)] - w[(j, i)]).abs() < 1e-12, "asymmetric at ({i},{j})");
                if i != j && (!live[i] || !live[j]) {
                    assert_eq!(w[(i, j)], 0.0, "dead node {i}<->{j} carries weight");
                }
            }
            if !live[i] {
                assert_eq!(w[(i, i)], 1.0, "dead node {i} must hold its value");
            }
        }
    });
}

#[test]
fn prop_dirichlet_partition_covers_every_sample_exactly_once() {
    // The non-IID shard axis: at any α and any label layout, the
    // partition is exact — every sample index lands on exactly one node,
    // nothing is dropped, nothing is duplicated.
    check("dirichlet partition is an exact cover", CASES, |g| {
        let n_nodes = g.usize_in(1, 12);
        let n_classes = g.usize_in(1, 6);
        let n_samples = g.usize_in(n_classes, 400);
        let labels: Vec<usize> = (0..n_samples).map(|_| g.usize_in(0, n_classes - 1)).collect();
        let alpha = *g.choose(&[0.05f64, 0.3, 1.0, 10.0, 100.0]);
        let parts = decomp::data::dirichlet_partition(
            n_nodes,
            &labels,
            n_classes,
            alpha,
            g.rng.next_u64(),
        );
        assert_eq!(parts.len(), n_nodes);
        let mut seen = vec![0u32; n_samples];
        for p in &parts {
            for &idx in p {
                assert!(idx < n_samples, "index {idx} out of range");
                seen[idx] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c == 1),
            "partition must cover every sample exactly once (alpha={alpha})"
        );
    });
}

#[test]
fn prop_vecops_linearity() {
    check("axpby linearity & dot symmetry", CASES, |g| {
        let n = g.usize_in(1, 300);
        let a = g.vec_f32(n, n, 1.0);
        let b = g.vec_f32(n, n, 1.0);
        assert!((vecops::dot(&a, &b) - vecops::dot(&b, &a)).abs() < 1e-6);
        let alpha = g.f32_in(-2.0, 2.0);
        let mut y = b.clone();
        vecops::axpby(alpha, &a, 0.0, &mut y);
        for (yi, ai) in y.iter().zip(&a) {
            assert!((yi - alpha * ai).abs() < 1e-5);
        }
        let nrm = vecops::norm2(&a);
        assert!((nrm * nrm - vecops::dot(&a, &a)).abs() < 1e-3 * (1.0 + nrm * nrm));
    });
}

// ---------------------------------------------------------------------------
// The streaming results plane (PR 7): the push writer must be
// byte-identical to the retired tree emitter, and the pull reader must
// see exactly the event stream `Json::parse` would have built.

/// The tree emitter `Json` shipped before the streaming writer,
/// reimplemented verbatim as an in-test oracle (compact `write`, pretty
/// `write_pretty`, `write_num`, `write_str`). `Json::to_string` /
/// `to_pretty` now delegate to `JsonWriter`, so comparing against this
/// oracle pins the streaming path byte-for-byte to the old output.
mod tree_oracle {
    use decomp::util::json::Json;

    fn write_num(x: f64, out: &mut String) {
        if !x.is_finite() {
            out.push_str("null");
        } else if x.fract() == 0.0 && x.abs() < 1e15 {
            out.push_str(&format!("{}", x as i64));
        } else {
            out.push_str(&format!("{x}"));
        }
    }

    fn write_str(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn indent(out: &mut String, depth: usize) {
        for _ in 0..depth {
            out.push_str("  ");
        }
    }

    fn write(v: &Json, out: &mut String) {
        match v {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, x) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write(x, out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    write(x, out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(v: &Json, out: &mut String, depth: usize) {
        match v {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, x) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_pretty(x, out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    indent(out, depth + 1);
                    write_str(k, out);
                    out.push_str(": ");
                    write_pretty(x, out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            other => write(other, out),
        }
    }

    pub fn compact(v: &Json) -> String {
        let mut out = String::new();
        write(v, &mut out);
        out
    }

    pub fn pretty(v: &Json) -> String {
        let mut out = String::new();
        write_pretty(v, &mut out, 0);
        out.push('\n');
        out
    }
}

/// Random `Json` trees with adversarial strings (escapes, control
/// chars, unicode) and the number shapes the old emitter special-cased
/// (integers, non-finite, negative zero).
fn random_json_nasty(g: &mut Gen, depth: usize) -> decomp::util::json::Json {
    use decomp::util::json::Json;
    let nasty = [
        "plain",
        "quo\"te",
        "back\\slash",
        "tab\tnl\ncr\r",
        "ctrl\u{1}\u{1f}",
        "uni — λ∞ 🚀",
        "",
    ];
    match if depth > 2 { g.usize_in(0, 4) } else { g.usize_in(0, 6) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num(match g.usize_in(0, 4) {
            0 => g.usize_in(0, 1_000_000) as f64,
            1 => -(g.usize_in(0, 1_000_000) as f64),
            2 => (g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0,
            3 => g.f64_in(-1.0, 1.0) * 1e-7,
            _ => f64::NAN,
        }),
        3 => Json::Str(format!("{}{}", g.choose(&nasty), g.usize_in(0, 99))),
        4 => Json::Str((*g.choose(&nasty)).to_string()),
        5 => Json::Arr(
            (0..g.usize_in(0, 4))
                .map(|_| random_json_nasty(g, depth + 1))
                .collect(),
        ),
        _ => Json::Obj(
            (0..g.usize_in(0, 4))
                .map(|i| {
                    (
                        format!("{}{i}", g.choose(&nasty)),
                        random_json_nasty(g, depth + 1),
                    )
                })
                .collect(),
        ),
    }
}

#[test]
fn prop_streaming_writer_byte_identical_to_tree_emitter() {
    use decomp::util::json::JsonWriter;
    check("JsonWriter == retired tree emitter, compact+pretty", CASES, |g| {
        let v = random_json_nasty(g, 0);
        // The doc(hidden) adapters route through the streaming writer.
        assert_eq!(v.to_string(), tree_oracle::compact(&v));
        assert_eq!(v.to_pretty(), tree_oracle::pretty(&v));
        // And so does driving the writer directly.
        let mut buf = Vec::new();
        let mut w = JsonWriter::new(&mut buf);
        w.value(&v).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), tree_oracle::compact(&v));
    });
}

/// Rebuild a `Json` tree from a pull-parser event stream.
fn rebuild_from_events(
    p: &mut decomp::util::json::JsonPull,
    first: decomp::util::json::Event,
) -> decomp::util::json::Json {
    use decomp::util::json::{Event, Json};
    use std::collections::BTreeMap;
    match first {
        Event::Null => Json::Null,
        Event::Bool(b) => Json::Bool(b),
        Event::Num(n) => Json::Num(n.as_f64()),
        Event::Str(s) => Json::Str(s.into_owned()),
        Event::BeginArr => {
            let mut items = Vec::new();
            loop {
                let e = p.next().expect("event in array");
                if e == Event::EndArr {
                    return Json::Arr(items);
                }
                items.push(rebuild_from_events(p, e));
            }
        }
        Event::BeginObj => {
            let mut m = BTreeMap::new();
            loop {
                match p.next().expect("event in object") {
                    Event::EndObj => return Json::Obj(m),
                    Event::Key(k) => {
                        let key = k.into_owned();
                        let e = p.next().expect("value after key");
                        m.insert(key, rebuild_from_events(p, e));
                    }
                    other => panic!("expected key or end-of-object, got {other:?}"),
                }
            }
        }
        other => panic!("expected a value event, got {other:?}"),
    }
}

#[test]
fn prop_pull_events_equivalent_to_tree_parse() {
    use decomp::util::json::{Event, Json, JsonPull};
    check("JsonPull events rebuild to Json::parse on the full grammar", CASES, |g| {
        let v = random_json_nasty(g, 0);
        for src in [v.to_string(), v.to_pretty()] {
            let via_tree = Json::parse(&src).unwrap();
            let mut p = JsonPull::new(&src);
            let first = p.next().unwrap();
            let via_pull = rebuild_from_events(&mut p, first);
            assert_eq!(via_pull, via_tree, "source: {src}");
            assert_eq!(p.next().unwrap(), Event::End);
        }
    });
}

#[test]
fn pull_event_equivalence_survives_nesting_depth_80() {
    use decomp::util::json::{Event, Json, JsonPull};
    // Past 64 levels the writer/reader bitstacks spill into a second
    // word — the exact boundary a single-u64 depth mask would get wrong.
    let mut src = String::from(r#"{"leaf":[1,2.5,"s"]}"#);
    for d in 0..80 {
        src = if d % 2 == 0 {
            format!("[{src}]")
        } else {
            format!("{{\"d{d}\":{src}}}")
        };
    }
    let via_tree = Json::parse(&src).unwrap();
    let mut p = JsonPull::new(&src);
    let first = p.next().unwrap();
    assert_eq!(rebuild_from_events(&mut p, first), via_tree);
    assert_eq!(p.next().unwrap(), Event::End);
    // The streaming writer round-trips the same document byte-for-byte
    // against the tree oracle at that depth.
    assert_eq!(via_tree.to_string(), tree_oracle::compact(&via_tree));
    assert_eq!(via_tree.to_pretty(), tree_oracle::pretty(&via_tree));
}

// ---------------------------------------------------------------------------
// Bounded-staleness execution (PR 10): the bounded executor is total
// over the (quorum, bound, drops, topology) space the spec layer
// admits, and relaxing the barrier never slows the virtual clock.

#[test]
fn prop_bounded_staleness_executor_total_and_never_slower_than_sync() {
    use decomp::data::{build_models, ModelKind, SynthSpec};
    use decomp::network::cost::{CostModel, NetworkModel};
    use decomp::network::sim::SimOpts;
    use decomp::spec::ExperimentSpec;
    check("bounded staleness total, makespan <= sync", CASES / 8, |g| {
        let n = g.usize_in(6, 12);
        let topo = if g.bool() {
            "ring".to_string()
        } else {
            format!("random_p40_s{}", g.usize_in(1, 99))
        };
        // Fixed-wire-size EF codecs only: their frame timings are
        // value-independent, which is what makes the makespan
        // comparison exact rather than statistical.
        let (comp, eta) = *g.choose(&[("q4", 0.5f32), ("sign", 0.4)]);
        let quorum = g.usize_in(1, 99);
        let rounds = g.usize_in(1, 3);
        let scenario = match *g.choose(&[0usize, 5, 10]) {
            0 => "static".to_string(),
            p => format!("dropln_p{p}"),
        };
        let spec = SynthSpec {
            n_nodes: n,
            dim: 16,
            rows_per_node: 4,
            ..Default::default()
        };
        let kind = ModelKind::Quadratic { spread: 1.0, noise: 0.1 };
        let seed = g.rng.next_u64();
        let run = |staleness: String| {
            let (models, x0) = build_models(&kind, &spec);
            let exp = ExperimentSpec::parse("choco", comp, &topo, n, seed, eta)
                .unwrap()
                .with_scenario(&scenario)
                .unwrap()
                .with_staleness(&staleness)
                .unwrap();
            let sim = SimOpts {
                cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
                compute_per_iter_s: 0.0,
                scenario: None,
                staleness: None,
            };
            exp.session()
                .unwrap()
                .run_simulated(models, &x0, 0.05, 8, sim)
                .unwrap_or_else(|e| panic!("{staleness} on {topo}: {e}"))
        };
        let bounded = run(format!("quorum_q{quorum}_s{rounds}"));
        let sync = run("sync".to_string());
        for r in &bounded.reports {
            assert!(r.losses.iter().all(|l| l.is_finite()), "node {} losses", r.node);
            assert!(r.final_x.iter().all(|v| v.is_finite()), "node {} params", r.node);
        }
        assert!(
            bounded.virtual_time_s <= sync.virtual_time_s * (1.0 + 1e-12),
            "quorum_q{quorum}_s{rounds} on {topo}: bounded {} > sync {}",
            bounded.virtual_time_s,
            sync.virtual_time_s
        );
        // Byte accounting is barrier-independent: the same frames cross
        // the same links under either discipline (fixed wire sizes,
        // drop verdicts keyed on (round, phase, link) only).
        assert_eq!(bounded.payload_bytes, sync.payload_bytes, "{topo}/{scenario}");
        assert_eq!(bounded.frames, sync.frames, "{topo}/{scenario}");
        assert_eq!(bounded.frames_dropped, sync.frames_dropped, "{topo}/{scenario}");
    });
}
