//! The O(1)-allocation contract of the trace emission path.
//!
//! `TrainTrace::write_json` streams every point straight to the sink
//! through [`JsonWriter`]: no intermediate `Json` tree, no per-point
//! strings. The writer's only heap state is its two container bitstacks
//! (one word each at trace nesting depth), so the number of heap
//! allocations during emission must be **independent of the number of
//! trace points** — a 100× longer trace allocates exactly as often as a
//! short one.
//!
//! Asserted with a counting `#[global_allocator]` wrapped around the
//! system allocator. This file intentionally contains a single test: a
//! concurrently running test would pollute the global counter.

use decomp::algorithms::{TracePoint, TrainTrace};
use decomp::obs::{Ctr, Hst, Registry};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// Same shape as the `trace_emit` bench group's synthetic trace:
/// realistic floats, u64 counters that overflow f32, a non-trivial algo
/// label.
fn synthetic(points: usize) -> TrainTrace {
    TrainTrace {
        algo: "trace_emit_pin".to_string(),
        points: (0..points)
            .map(|i| TracePoint {
                iter: i,
                global_loss: 1.0 / (1.0 + i as f64),
                consensus: 0.5 / (1.0 + i as f64),
                bytes_sent: i as u64 * 123_456_789,
                sim_time_s: i as f64 * 0.01,
            })
            .collect(),
    }
}

/// Allocations during one `write_json` into a no-op sink (the sink
/// itself never allocates, so this isolates the emitter).
fn emission_allocs(trace: &TrainTrace, pretty: bool) -> u64 {
    let before = alloc_count();
    trace.write_json(std::io::sink(), pretty).unwrap();
    alloc_count() - before
}

#[test]
fn trace_emission_allocations_are_constant_in_point_count() {
    // Build both traces (and run one warm-up emission each) before any
    // counting: trace construction allocates freely, emission must not.
    let short = synthetic(1_000);
    let long = synthetic(100_000);
    for pretty in [false, true] {
        emission_allocs(&short, pretty);
        emission_allocs(&long, pretty);
    }

    for pretty in [false, true] {
        let a_short = emission_allocs(&short, pretty);
        let a_long = emission_allocs(&long, pretty);
        assert_eq!(
            a_short, a_long,
            "emitting 100k points allocated {a_long} time(s) vs {a_short} for 1k \
             (pretty={pretty}); emission must be O(1) in trace length"
        );
        assert!(
            a_short <= 8,
            "trace emission allocated {a_short} time(s) (pretty={pretty}); \
             expected only the writer's fixed bitstack state"
        );
    }

    // The instrumentation plane's registry is preallocated inline state:
    // recording counters, observing histograms, and the shard-order
    // merge are array writes and must allocate exactly zero times.
    // (Same file, same test: the global counter stays unpolluted.)
    let mut a = Registry::new();
    let mut b = Registry::new();
    let before = alloc_count();
    for i in 0..100_000u64 {
        a.add(Ctr::Frames, 1);
        a.add(Ctr::PayloadBytes, i);
        a.observe(Hst::WireBytes, i);
        b.observe(Hst::FrameLatencyNs, i.wrapping_mul(0x9e37_79b9));
        if i % 1024 == 0 {
            a.merge_from(&mut b);
        }
    }
    let reg_allocs = alloc_count() - before;
    assert_eq!(
        reg_allocs, 0,
        "registry record/merge allocated {reg_allocs} time(s); \
         counters and histograms must be preallocated inline cells"
    );
    assert_eq!(a.counter(Ctr::Frames), 100_000);
}
