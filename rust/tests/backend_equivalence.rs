//! Discrete-event engine ≡ threaded coordinator, bitwise.
//!
//! The tentpole guarantee of the sim backend: executing the *same*
//! per-node programs on the single-threaded event engine produces exactly
//! the trajectory the thread-per-node coordinator produces — while the
//! engine also scales the fig3 network sweep to n = 64, which
//! thread-per-node cannot do representatively.

use decomp::algorithms::AlgoConfig;
use decomp::compression;
use decomp::coordinator::{run_simulated, run_threaded};
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::experiments::fig3;
use decomp::models::GradientModel;
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::SimOpts;
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::sync::Arc;

fn setup(
    n: usize,
    dim: usize,
    compressor: &str,
    seed: u64,
) -> (AlgoConfig, Vec<Box<dyn GradientModel>>, Vec<Box<dyn GradientModel>>, Vec<f32>) {
    let spec = SynthSpec {
        n_nodes: n,
        rows_per_node: 64,
        dim,
        noise: 0.1,
        heterogeneity: 0.5,
        seed: 0xabc,
    };
    let kind = ModelKind::Logistic { batch: 4 };
    let (m1, x0) = build_models(&kind, &spec);
    let (m2, _) = build_models(&kind, &spec);
    let (comp, link) = compression::resolve_name(compressor).unwrap();
    let cfg = AlgoConfig {
        mixing: Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n))),
        compressor: comp,
        seed,
        eta: 1.0,
        link,
        scenario: None,
    };
    (cfg, m1, m2, x0)
}

fn clone_cfg(cfg: &AlgoConfig) -> AlgoConfig {
    AlgoConfig {
        mixing: cfg.mixing.clone(),
        compressor: cfg.compressor.clone(),
        seed: cfg.seed,
        eta: cfg.eta,
        link: cfg.link.clone(),
        scenario: cfg.scenario.clone(),
    }
}

/// The acceptance shape: 8-node ring, 40 iterations, bitwise equality of
/// every node's trajectory endpoint plus byte/loss accounting.
fn assert_backends_bitwise(algo_name: &str, compressor: &str) {
    let n = 8;
    let dim = 48;
    let iters = 40;
    let gamma = 0.05;
    let (mut cfg, m_sim, m_thr, x0) = setup(n, dim, compressor, 42);
    // Exercise the η ≠ 1 path for the error-feedback family.
    if matches!(algo_name, "choco" | "deepsqueeze") {
        cfg.eta = 0.4;
    }

    let sim = run_simulated(
        algo_name,
        &clone_cfg(&cfg),
        m_sim,
        &x0,
        gamma,
        iters,
        SimOpts {
            // A non-trivial network: virtual time must not perturb math.
            cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
            staleness: None,
            compute_per_iter_s: 0.01,
            scenario: None,
        },
    )
    .unwrap();
    let thr = run_threaded(algo_name, &cfg, m_thr, &x0, gamma, iters).unwrap();

    assert_eq!(sim.reports.len(), thr.reports.len());
    for (sr, tr) in sim.reports.iter().zip(&thr.reports) {
        assert_eq!(sr.node, tr.node);
        for (d, (x, y)) in sr.final_x.iter().zip(&tr.final_x).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{algo_name}/{compressor}: node {} dim {d}: sim {x} vs threaded {y}",
                sr.node
            );
        }
        // Per-iteration minibatch losses agree bitwise too.
        assert_eq!(sr.losses.len(), tr.losses.len());
        for (a, b) in sr.losses.iter().zip(&tr.losses) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Payload accounting matches the mailbox transport's.
        assert_eq!(sr.bytes_sent, tr.bytes_sent, "node {} bytes", sr.node);
        assert_eq!(sr.msgs_sent, tr.msgs_sent, "node {} msgs", sr.node);
    }
    // The sim run also measured virtual time the threads backend cannot.
    assert!(sim.virtual_time_s > iters as f64 * 0.01);
    assert!(sim.frame_bytes > sim.payload_bytes);
}

#[test]
fn dcd_q8_sim_bitwise_equals_threads_on_8_ring() {
    assert_backends_bitwise("dcd", "q8");
}

#[test]
fn ecd_q8_sim_bitwise_equals_threads_on_8_ring() {
    assert_backends_bitwise("ecd", "q8");
}

#[test]
fn dpsgd_fp32_sim_bitwise_equals_threads() {
    assert_backends_bitwise("dpsgd", "fp32");
}

#[test]
fn naive_q8_sim_bitwise_equals_threads() {
    assert_backends_bitwise("naive", "q8");
}

#[test]
fn allreduce_fp32_sim_bitwise_equals_threads() {
    assert_backends_bitwise("allreduce", "fp32");
}

#[test]
fn qallreduce_q8_sim_bitwise_equals_threads() {
    assert_backends_bitwise("qallreduce", "q8");
}

#[test]
fn dcd_q4_sim_bitwise_equals_threads() {
    assert_backends_bitwise("dcd", "q4");
}

#[test]
fn choco_q8_sim_bitwise_equals_threads_on_8_ring() {
    assert_backends_bitwise("choco", "q8");
}

#[test]
fn choco_sign_sim_bitwise_equals_threads() {
    assert_backends_bitwise("choco", "sign");
}

#[test]
fn choco_topk_sim_bitwise_equals_threads() {
    assert_backends_bitwise("choco", "topk_25");
}

#[test]
fn deepsqueeze_q4_sim_bitwise_equals_threads() {
    assert_backends_bitwise("deepsqueeze", "q4");
}

#[test]
fn deepsqueeze_topk_sim_bitwise_equals_threads() {
    assert_backends_bitwise("deepsqueeze", "topk_25");
}

#[test]
fn deepsqueeze_sign_sim_bitwise_equals_threads() {
    assert_backends_bitwise("deepsqueeze", "sign");
}

#[test]
fn choco_lowrank_r2_sim_bitwise_equals_threads() {
    // The link-state family: warm-started per-link power-iteration state
    // must evolve identically on both backends (one compress per node
    // per iteration, executor-independent).
    assert_backends_bitwise("choco", "lowrank_r2");
}

#[test]
fn choco_lowrank_r4_sim_bitwise_equals_threads() {
    assert_backends_bitwise("choco", "lowrank_r4");
}

#[test]
fn fig3_sweep_runs_at_n64_on_sim_backend() {
    // The fig3 network sweep at 64 nodes, executed (not closed-formed) on
    // the event engine — now including the error-feedback family and the
    // low-rank link family.
    let pts = fig3::sim_sweep_points(&[64], 3, NetworkModel::new(5e6, 5e-3));
    // dpsgd_fp32, dcd_q8, ecd_q8, choco_sign, choco_lowrank_r4,
    // deepsqueeze_topk_25.
    assert_eq!(pts.len(), 6);
    for p in &pts {
        assert_eq!(p.n, 64);
        assert!(p.virtual_s_per_iter.is_finite() && p.virtual_s_per_iter > 0.0);
        assert!(p.payload_per_node_iter > 0.0);
    }
    let fp = pts.iter().find(|p| p.algo == "dpsgd_fp32").unwrap();
    let q8 = pts.iter().find(|p| p.algo == "dcd_q8").unwrap();
    let sign = pts.iter().find(|p| p.algo == "choco_sign").unwrap();
    assert!(
        q8.virtual_s_per_iter < 0.5 * fp.virtual_s_per_iter,
        "compression must win at 5 Mbps: q8 {} vs fp {}",
        q8.virtual_s_per_iter,
        fp.virtual_s_per_iter
    );
    // 1-bit sign moves ~1/32 the payload of fp32.
    assert!(
        sign.payload_per_node_iter < 0.05 * fp.payload_per_node_iter,
        "sign {} vs fp {}",
        sign.payload_per_node_iter,
        fp.payload_per_node_iter
    );
}

#[test]
fn ef_sweep_biased_compressors_converge_at_n64() {
    // Acceptance: the EF sweep runs at n = 64 on the sim backend and the
    // biased compressors (top-k, sign) land within 10% of full-precision
    // D-PSGD in quick mode. (The same bar is asserted module-side; this
    // pins it from the integration suite where the backend matrix lives.)
    use decomp::experiments::ef_sweep;
    use decomp::network::cost::NetCondition;
    let rows = ef_sweep::sweep_condition(64, 150, true, NetCondition::Worst);
    let loss = |name: &str| {
        rows.iter()
            .find(|r| r.algo == name)
            .unwrap_or_else(|| panic!("{name} missing"))
            .final_loss
    };
    let base = loss("dpsgd_fp32");
    for name in ["choco_topk_25", "choco_sign"] {
        let l = loss(name);
        assert!(l.is_finite() && l <= 1.10 * base + 1e-9, "{name}: {l} vs {base}");
    }
}

#[test]
fn choco_lowrank_r4_within_10pct_of_dpsgd_at_10pct_wire() {
    // The low-rank acceptance bar, in the same harness shape as the PR 2
    // EF pins (n = 64 ring, sim backend, worst §5.2 condition, final
    // loss within 10% of dpsgd_fp32) — run at the lowranksweep workload
    // (dim 10000 → 100×100 fold), the regime where rank-4 factors are an
    // extreme compression.
    use decomp::experiments::lowrank_sweep;
    let rows = lowrank_sweep::acceptance_rows(100);
    assert_eq!(rows.len(), 2);
    let (fp, lr) = (&rows[0], &rows[1]);
    assert_eq!(fp.algo, "dpsgd_fp32");
    assert_eq!(lr.algo, "choco_lowrank_r4");
    assert!(lr.final_loss.is_finite(), "lowrank diverged");
    assert!(
        lr.final_loss <= 1.10 * fp.final_loss + 1e-9,
        "choco_lowrank_r4 {} vs dpsgd_fp32 {}",
        lr.final_loss,
        fp.final_loss
    );
    assert!(
        lr.final_loss < lr.init_loss,
        "choco_lowrank_r4 should improve: {} vs init {}",
        lr.final_loss,
        lr.init_loss
    );
    // Wire economy: rank-4 factors over the 100×100 fold are 8% of the
    // fp32 payload — the ≤10% acceptance bound with real margin.
    let ratio = lr.payload_bytes as f64 / fp.payload_bytes as f64;
    assert!(ratio <= 0.10, "lowrank payload ratio {ratio} above 10%");
    assert!(ratio > 0.0, "lowrank payload must be accounted");
    // And the measured virtual clock reflects it.
    assert!(lr.virtual_s < fp.virtual_s, "lowrank must be faster under Worst");
}

#[test]
fn sim_backend_trains_at_n64_ring() {
    // A real (small) training run at a scale the threaded backend cannot
    // sweep: 64 nodes, DCD q8, logistic shards.
    let n = 64;
    let (cfg, models, _, x0) = setup(n, 16, "q8", 7);
    let eval: Vec<Box<dyn GradientModel>> = {
        let spec = SynthSpec {
            n_nodes: n,
            rows_per_node: 64,
            dim: 16,
            noise: 0.1,
            heterogeneity: 0.5,
            seed: 0xabc,
        };
        build_models(&ModelKind::Logistic { batch: 4 }, &spec).0
    };
    let run = run_simulated(
        "dcd",
        &cfg,
        models,
        &x0,
        0.05,
        150,
        SimOpts {
            cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
            staleness: None,
            compute_per_iter_s: 0.0,
            scenario: None,
        },
    )
    .unwrap();
    assert_eq!(run.reports.len(), n);
    let mean = run.mean_params();
    let init: f64 = eval.iter().map(|m| m.full_loss(&x0)).sum::<f64>() / n as f64;
    let fin: f64 = eval.iter().map(|m| m.full_loss(&mean)).sum::<f64>() / n as f64;
    assert!(fin < 0.9 * init, "expected progress at n=64: {init} -> {fin}");
    // Every node sent degree × iters messages, batched into as many frames.
    for r in &run.reports {
        assert_eq!(r.msgs_sent, 150 * 2);
    }
}

#[test]
fn sim_straggler_grid_slows_virtual_time_not_math() {
    let (cfg, m_a, m_b, x0) = setup(8, 24, "q8", 9);
    let base = NetworkModel::new(1e8, 1e-3);
    let uniform = run_simulated(
        "dcd",
        &clone_cfg(&cfg),
        m_a,
        &x0,
        0.05,
        20,
        SimOpts {
            cost: CostModel::Uniform(base),
            staleness: None,
            compute_per_iter_s: 0.0,
            scenario: None,
        },
    )
    .unwrap();
    let straggled = run_simulated(
        "dcd",
        &cfg,
        m_b,
        &x0,
        0.05,
        20,
        SimOpts {
            cost: CostModel::uniform_with_stragglers(8, base, &[5], 10.0),
            staleness: None,
            compute_per_iter_s: 0.0,
            scenario: None,
        },
    )
    .unwrap();
    // The network grid changes time, never the trajectory.
    for (a, b) in uniform.reports.iter().zip(&straggled.reports) {
        for (x, y) in a.final_x.iter().zip(&b.final_x) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
    assert!(straggled.virtual_time_s > 5.0 * uniform.virtual_time_s);
}
