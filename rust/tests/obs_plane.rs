//! Integration pins for the instrumentation plane.
//!
//! What the plane promises (DESIGN.md §7b) and this file enforces
//! through public API only:
//!
//! - log2 histogram bins bracket every `u64` sample, with powers of two
//!   exact on lower edges;
//! - registries merged in shard order reproduce the serial totals for
//!   any partition of the sample stream;
//! - an instrumented run — counters, breakdown, *and the streamed
//!   Perfetto export* — is bit-identical across repeats and across
//!   event-loop shard counts;
//! - the n = 4 export has the pinned structure (track metadata per node
//!   and per directed link, one `frame` span per charged frame whose
//!   bytes sum to the run's on-wire total);
//! - through `Session::run_sim_traced`, the per-phase breakdown sums
//!   *bitwise* to the run's virtual time and the counters agree with
//!   the engine's own accounting.

use decomp::algorithms::{AlgoConfig, RunOpts};
use decomp::compression;
use decomp::coordinator::program::build_program;
use decomp::coordinator::ObsSettings;
use decomp::data::{build_models, ModelKind, SynthSpec};
use decomp::network::cost::{CostModel, NetworkModel};
use decomp::network::sim::{LinkTable, NodeProgram, SimEngine, SimOpts, SimRun};
use decomp::obs::trace::validate;
use decomp::obs::{CodecCost, Ctr, Histogram, Hst, Registry};
use decomp::spec::{ExperimentSpec, ObsSpec, TopologySpec};
use decomp::topology::{Graph, MixingMatrix, Topology};
use decomp::util::json::Json;
use decomp::util::rng::Pcg64;
use std::io;
use std::sync::{Arc, Mutex};

#[test]
fn histogram_bins_bracket_every_sample() {
    // Property: for arbitrary magnitudes, the assigned bin's lower edge
    // is ≤ the sample and the next bin's lower edge is > it.
    let mut rng = Pcg64::new(0x0b5_b1, 1);
    for _ in 0..4096 {
        let v = rng.next_u64() >> (rng.next_u64() % 64);
        let i = Histogram::bin_index(v);
        let lo = Histogram::bin_lower(i);
        assert!(lo <= v, "bin {i} lower edge {lo} above sample {v}");
        if i < 64 {
            assert!(v < Histogram::bin_lower(i + 1), "{v} beyond bin {i}");
        }
    }
    // Powers of two land exactly on lower edges; their predecessors
    // stay one bin below.
    for k in 1..64 {
        let v = 1u64 << k;
        assert_eq!(Histogram::bin_lower(Histogram::bin_index(v)), v);
        assert_eq!(Histogram::bin_index(v - 1), Histogram::bin_index(v) - 1);
    }
}

#[test]
fn shard_partitioned_registries_merge_to_the_serial_totals() {
    // The engine's determinism story rests on this: u64 cells make the
    // shard merge independent of how samples were partitioned.
    let mut rng = Pcg64::new(7, 2);
    let samples: Vec<u64> = (0..1000).map(|_| rng.next_u64() >> 32).collect();
    let mut serial = Registry::new();
    for &v in &samples {
        serial.add(Ctr::PayloadBytes, v);
        serial.observe(Hst::WireBytes, v);
    }
    for k in [2usize, 3, 4, 7] {
        let mut parts: Vec<Registry> = (0..k).map(|_| Registry::new()).collect();
        for (i, &v) in samples.iter().enumerate() {
            parts[i % k].add(Ctr::PayloadBytes, v);
            parts[i % k].observe(Hst::WireBytes, v);
        }
        let mut merged = Registry::new();
        for p in parts.iter_mut() {
            merged.merge_from(p);
            assert_eq!(p.counter(Ctr::PayloadBytes), 0, "merge_from drains");
        }
        assert_eq!(merged, serial, "merge of {k} partitions");
    }
}

/// Shared sink so the trace bytes survive the boxed writer.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl io::Write for SharedBuf {
    fn write(&mut self, b: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// One instrumented dpsgd_q8 ring cell on the event engine, with the
/// Perfetto export captured: returns the trace text and the run.
fn traced_run(n: usize, shards: usize) -> (String, SimRun) {
    let iters = 12usize;
    let spec = SynthSpec {
        n_nodes: n,
        dim: 32,
        rows_per_node: 8,
        ..Default::default()
    };
    let (models, x0) = build_models(&ModelKind::Quadratic { spread: 1.0, noise: 0.1 }, &spec);
    let (comp, link) = compression::resolve_name("q8").expect("compressor");
    let mixing = Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n)));
    let cfg = AlgoConfig {
        mixing,
        compressor: comp,
        seed: 0x0b5,
        eta: 1.0,
        link,
        scenario: None,
    };
    let mut programs: Vec<Box<dyn NodeProgram>> = models
        .into_iter()
        .enumerate()
        .map(|(node, model)| {
            build_program("dpsgd", &cfg, node, model, &x0, 0.05, iters).expect("program")
        })
        .collect();
    let opts = SimOpts {
        cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
        staleness: None,
        compute_per_iter_s: 0.01,
        scenario: None,
    };
    let links = LinkTable::from_graph(&cfg.mixing.graph).expect("ring links");
    let mut engine = SimEngine::with_links(n, opts, links, shards);
    engine.enable_obs("dpsgd_q8", CodecCost::per_elem(2, 1));
    let buf = SharedBuf::default();
    engine.set_trace_writer(Box::new(buf.clone())).unwrap();
    for t in 0..iters as u64 {
        engine.step(&mut programs, t);
    }
    let run = engine.finish(programs);
    let bytes = buf.0.lock().unwrap().clone();
    (String::from_utf8(bytes).unwrap(), run)
}

#[test]
fn instrumented_run_is_bit_identical_across_shards_and_repeats() {
    let (base_text, base_run) = traced_run(6, 1);
    let base_obs = base_run.obs.as_ref().expect("obs enabled");
    for shards in [2usize, 4] {
        let (text, run) = traced_run(6, shards);
        assert_eq!(text, base_text, "trace bytes at {shards} shards");
        let obs = run.obs.as_ref().unwrap();
        assert_eq!(obs.reg, base_obs.reg, "registry at {shards} shards");
        assert_eq!(run.virtual_time_s.to_bits(), base_run.virtual_time_s.to_bits());
        assert_eq!(
            obs.breakdown_total().to_bits(),
            base_obs.breakdown_total().to_bits()
        );
    }
    // A repeat at the same shard count is bytewise identical too.
    let (again, _) = traced_run(6, 1);
    assert_eq!(again, base_text, "trace bytes across repeats");
}

#[test]
fn perfetto_export_structure_pins_at_n4() {
    let (text, run) = traced_run(4, 1);
    let stats = validate(&text).expect("export validates");
    let doc = Json::parse(&text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), stats.events);

    // Track metadata: both process groups, one track per node, one per
    // directed ring link (2n).
    let metas: Vec<(&str, &str)> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("M"))
        .map(|e| {
            let args_name = e.get("args").unwrap().get("name").unwrap();
            (
                e.get("name").unwrap().as_str().unwrap(),
                args_name.as_str().unwrap(),
            )
        })
        .collect();
    assert!(metas.contains(&("process_name", "nodes")));
    assert!(metas.contains(&("process_name", "links")));
    let tracks = |pred: &dyn Fn(&str) -> bool| {
        metas
            .iter()
            .filter(|&&(k, v)| k == "thread_name" && pred(v))
            .count()
    };
    assert_eq!(tracks(&|v| v.starts_with("node ")), 4);
    assert_eq!(tracks(&|v| v.starts_with("link ")), 8);

    // Exactly one `frame` span per charged frame; their byte args sum
    // to the run's on-wire total; every span sits on the virtual clock.
    let mut frame_spans = 0u64;
    let mut frame_bytes = 0u64;
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) != Some("X") {
            continue;
        }
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0, "{e:?}");
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0, "{e:?}");
        let name = e.get("name").unwrap().as_str().unwrap();
        assert!(matches!(name, "compute" | "wait" | "frame"), "{name}");
        if name == "frame" {
            frame_spans += 1;
            frame_bytes += e.get("args").unwrap().get("bytes").unwrap().as_usize().unwrap() as u64;
        }
    }
    assert_eq!(frame_spans, run.frames);
    assert_eq!(frame_bytes, run.frame_bytes);
}

#[test]
fn session_breakdown_closes_bitwise_and_counters_agree() {
    let spec = SynthSpec {
        n_nodes: 8,
        dim: 16,
        rows_per_node: 8,
        ..Default::default()
    };
    let kind = ModelKind::Quadratic { spread: 1.0, noise: 0.1 };
    let (models, x0) = build_models(&kind, &spec);
    let (eval_models, _) = build_models(&kind, &spec);
    let exp = ExperimentSpec {
        algo: "choco".parse().unwrap(),
        compressor: "topk_25".parse().unwrap(),
        topology: TopologySpec::Ring,
        n_nodes: 8,
        seed: 11,
        eta: 0.5,
        scenario: Default::default(),
        staleness: Default::default(),
    };
    let session = exp.session().unwrap();
    let opts = RunOpts {
        iters: 10,
        gamma: 0.05,
        eval_every: 5,
        ..RunOpts::default()
    };
    let sim = SimOpts {
        cost: CostModel::Uniform(NetworkModel::new(5e6, 5e-3)),
        staleness: None,
        compute_per_iter_s: 0.01,
        scenario: None,
    };
    let obs_on = ObsSettings {
        spec: ObsSpec::Counters,
        trace_out: None,
    };
    let traced = session
        .run_sim_traced(models, &eval_models, &x0, &opts, sim.clone(), obs_on)
        .unwrap();
    let obs = traced.run.obs.as_ref().expect("counters on");

    // The acceptance pin: compute + per-phase splits sum to the virtual
    // clock bitwise, not approximately.
    assert_eq!(obs.breakdown_total().to_bits(), traced.run.virtual_time_s.to_bits());
    assert_eq!(obs.n, 8);
    assert_eq!(obs.reg.counter(Ctr::Frames), traced.run.frames);
    assert_eq!(obs.reg.counter(Ctr::PayloadBytes), traced.run.payload_bytes);
    assert_eq!(obs.reg.counter(Ctr::FrameBytes), traced.run.frame_bytes);
    assert_eq!(obs.reg.hist(Hst::WireBytes).count(), traced.run.frames);
    assert!(obs.codec_virtual_s() > 0.0, "top-k codec cost recorded");
    assert_eq!(
        traced.trace.points.last().unwrap().bytes_sent,
        traced.run.payload_bytes
    );

    // The observed trajectory is the plain trajectory: observation never
    // moves the clock or the losses.
    let (models2, _) = build_models(&kind, &spec);
    let plain = session
        .run_sim_traced(models2, &eval_models, &x0, &opts, sim, ObsSettings::off())
        .unwrap();
    assert!(plain.run.obs.is_none());
    assert_eq!(
        plain.run.virtual_time_s.to_bits(),
        traced.run.virtual_time_s.to_bits()
    );
    for (a, b) in plain.trace.points.iter().zip(&traced.trace.points) {
        assert_eq!(a.global_loss.to_bits(), b.global_loss.to_bits());
    }
}
