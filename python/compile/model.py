"""L2: decoder-only transformer LM over a flat f32 parameter vector.

The rust coordinator treats every model as x ∈ R^N (the paper's view), so
this module packs all transformer weights into one flat vector and
exposes:

  - ``loss_fn(flat, tokens)``            — next-token cross entropy
  - ``grad_step(flat, tokens)``          — (loss, grads_flat), the artifact
  - ``dcd_fused_step(...)``              — the full DCD-PSGD local step
    (gossip kernel + fwd/bwd + Pallas quantization) as ONE jitted graph:
    the entire per-iteration compute of a node in a single PJRT call.

Layers are stacked on a leading axis and consumed with ``lax.scan`` so the
lowered HLO stays compact regardless of depth. Output head is weight-tied
to the token embedding.
"""

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import gossip as gossip_k
from .kernels import quantize as quantize_k
from .kernels.ref import CHUNK


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# ---------------------------------------------------------------------------
# Flat parameter packing


def param_shapes(cfg: ModelConfig):
    """Ordered (name, shape) table defining the flat layout."""
    L, D, F, V, S = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab, cfg.seq_len
    return [
        ("embed", (V, D)),
        ("pos", (S, D)),
        ("ln1_scale", (L, D)),
        ("ln1_bias", (L, D)),
        ("wqkv", (L, D, 3 * D)),
        ("wo", (L, D, D)),
        ("ln2_scale", (L, D)),
        ("ln2_bias", (L, D)),
        ("w1", (L, D, F)),
        ("b1", (L, F)),
        ("w2", (L, F, D)),
        ("b2", (L, D)),
        ("lnf_scale", (D,)),
        ("lnf_bias", (D,)),
    ]


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_shapes(cfg):
        k = 1
        for s in shape:
            k *= s
        total += k
    return total


def unflatten(cfg: ModelConfig, flat):
    """Slice the flat vector into the named parameter dict."""
    params = {}
    off = 0
    for name, shape in param_shapes(cfg):
        k = 1
        for s in shape:
            k *= s
        params[name] = flat[off : off + k].reshape(shape)
        off += k
    return params


def init_flat(cfg: ModelConfig, seed: int = 0):
    """Deterministic initialization of the flat vector (shared x_1)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_shapes(cfg):
        key, sub = jax.random.split(key)
        k = 1
        for s in shape:
            k *= s
        if name.endswith("_scale"):
            chunks.append(jnp.ones(k, dtype=jnp.float32))
        elif name.endswith("_bias") or name.startswith("b"):
            chunks.append(jnp.zeros(k, dtype=jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = fan_in ** -0.5
            chunks.append(
                (jax.random.normal(sub, (k,), dtype=jnp.float32) * std)
            )
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Forward / loss


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _block(cfg: ModelConfig, h, layer):
    """One pre-LN transformer block. h: (B, S, D)."""
    B, S, D = h.shape
    H, hd = cfg.n_heads, cfg.head_dim

    a = _layer_norm(h, layer["ln1_scale"], layer["ln1_bias"])
    qkv = a @ layer["wqkv"]  # (B, S, 3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (hd ** -0.5)
    mask = jnp.tril(jnp.ones((S, S), dtype=bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
    h = h + o @ layer["wo"]

    m = _layer_norm(h, layer["ln2_scale"], layer["ln2_bias"])
    m = jax.nn.gelu(m @ layer["w1"] + layer["b1"])
    h = h + m @ layer["w2"] + layer["b2"]
    return h


_LAYER_KEYS = (
    "ln1_scale",
    "ln1_bias",
    "wqkv",
    "wo",
    "ln2_scale",
    "ln2_bias",
    "w1",
    "b1",
    "w2",
    "b2",
)


def forward(cfg: ModelConfig, params, tokens):
    """Logits for a batch of token ids. tokens: i32 (B, S)."""
    B, S = tokens.shape
    h = params["embed"][tokens] + params["pos"][None, :S]
    stacked = {k: params[k] for k in _LAYER_KEYS}

    def body(h, layer):
        return _block(cfg, h, layer), None

    h, _ = jax.lax.scan(body, h, stacked)
    h = _layer_norm(h, params["lnf_scale"], params["lnf_bias"])
    return h @ params["embed"].T  # weight-tied head


def loss_fn(cfg: ModelConfig, flat, tokens):
    """Next-token cross entropy. tokens: i32 (B, S+1) — inputs tokens[:, :-1],
    targets tokens[:, 1:]."""
    params = unflatten(cfg, flat)
    logits = forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def grad_step(cfg: ModelConfig, flat, tokens):
    """(loss, grads_flat) — the main AOT artifact."""
    return jax.value_and_grad(functools.partial(loss_fn, cfg))(flat, tokens)


# ---------------------------------------------------------------------------
# The fused DCD-PSGD local step (one PJRT call per node per iteration)


def padded_dim(cfg: ModelConfig) -> int:
    n = param_count(cfg)
    return ((n + CHUNK - 1) // CHUNK) * CHUNK


def dcd_fused_step(cfg: ModelConfig, x, neighbors, weights, gamma, tokens, seed, bits=8):
    """One full DCD-PSGD iteration for one node, fused into one graph.

    Args:
      x: f32[Np] local model, zero-padded to a CHUNK multiple.
      neighbors: f32[deg, Np] neighbor replicas (≡ their actual models).
      weights: f32[deg + 1] mixing row (self weight first).
      gamma: f32[1] step size.
      tokens: i32[B, S+1] local minibatch.
      seed: i32[1] compression stream for this (node, iteration).

    Returns:
      loss: f32[]            minibatch loss at x_t
      x_new: f32[Np]         x_{t+1} = x_t + C(z_t)
      levels: f32[Np]        quantization levels of z_t (the wire payload)
      scales: f32[Np/CHUNK]  per-chunk scales (the rest of the payload)
    """
    n = param_count(cfg)
    loss, g = grad_step(cfg, x[:n], tokens)
    g_pad = jnp.concatenate([g, jnp.zeros(x.shape[0] - n, dtype=jnp.float32)])
    # Step 1 (gossip kernel): x_{t+1/2} = Σ_j W_ij x̂_j − γ g.
    x_half = gossip_k.gossip_step(x, neighbors, weights, gamma, g_pad)
    # Step 2 (quantize kernel): z = x_{t+1/2} − x_t, compress.
    z = x_half - x
    levels, scales = quantize_k.quantize(z, seed, bits=bits)
    cz = quantize_k.dequantize(levels, scales, bits=bits)
    # Step 3: x_{t+1} = x_t + C(z).
    return loss, x + cz, levels, scales


# ---------------------------------------------------------------------------
# Synthetic corpus (byte-level, deterministic) for the e2e driver's tests


def synthetic_tokens(cfg: ModelConfig, batch: int, seed: int, node: int = 0):
    """A learnable synthetic token stream: a noisy order-1 Markov chain
    whose transition structure differs slightly per node (heterogeneity).
    """
    key = jax.random.PRNGKey(seed * 1000003 + node)
    k1, k2 = jax.random.split(key)
    # Base sequence: x_{t+1} = (a * x_t + b + noise) mod vocab.
    a, b = 31, 17 + node
    x0 = jax.random.randint(k1, (batch, 1), 0, cfg.vocab)
    noise = jax.random.bernoulli(k2, 0.1, (batch, cfg.seq_len)).astype(jnp.int32)

    def step(x, n):
        nxt = (a * x + b + n) % cfg.vocab
        return nxt, nxt

    _, seq = jax.lax.scan(step, x0[:, 0], noise.T)
    return jnp.concatenate([x0, seq.T], axis=1).astype(jnp.int32)  # (B, S+1)
