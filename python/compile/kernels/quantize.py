"""L1 Pallas kernel: stochastic uniform quantization (the paper's
compression hot spot, footnote 1) and its inverse.

The kernel streams the parameter-delta vector through VMEM one scale-chunk
at a time (BlockSpec blocks of (1, CHUNK) over a (nchunks, CHUNK) view —
CHUNK = 1024 = 8×128, a multiple of the TPU lane tile), computes the
per-chunk max-abs scale on the VPU, stochastically rounds against a
counter-based hash RNG (no state to carry between blocks, so blocks are
trivially parallel), and writes integer levels plus one scale per chunk.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CUDA
implementation fused quantization into one pass over the gradient in
global memory; here BlockSpec expresses the same HBM→VMEM schedule.
interpret=True everywhere — the CPU PJRT client cannot run Mosaic
custom-calls; structure, not wallclock, is what carries to TPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import CHUNK


def _hash_uniform_u32(seed_u32, idx_i32):
    """In-kernel twin of ref.hash_uniform (murmur3 finalizer)."""
    x = (idx_i32.astype(jnp.uint32) * jnp.uint32(2654435761)) ^ seed_u32
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


# Scale-chunks processed per grid program. §Perf: one program per chunk
# (R=1) spends most of interpret-mode wallclock in grid bookkeeping, and
# on real TPUs under-fills VMEM (4 KiB/block vs ≈16 MiB available).
# R=32 chunks → 128 KiB blocks: 32× fewer grid steps, still far below the
# VMEM ceiling, and the per-row reduction stays a lane-wise VPU max.
ROWS_PER_BLOCK = 32


def _pad_rows(mat, rows_mult):
    rows = mat.shape[0]
    padded = ((rows + rows_mult - 1) // rows_mult) * rows_mult
    if padded == rows:
        return mat
    pad = jnp.zeros((padded - rows,) + mat.shape[1:], dtype=mat.dtype)
    return jnp.concatenate([mat, pad], axis=0)


def _quantize_kernel(z_ref, seed_ref, lev_ref, scale_ref, *, bits, chunk, rows):
    i = pl.program_id(0)
    z = z_ref[...]  # (rows, chunk) block in VMEM
    s = jnp.max(jnp.abs(z), axis=1, keepdims=True)  # (rows, 1)
    lm1 = jnp.float32(2**bits - 1)
    safe = jnp.where(s > 0, s, 1.0)
    u = jnp.clip((z / safe + 1.0) * 0.5 * lm1, 0.0, lm1)
    lo = jnp.floor(u)
    frac = u - lo
    # Global element index = (block row offset + row)·chunk + lane: the
    # stateless RNG counter (blocks stay order-independent).
    row = jax.lax.broadcasted_iota(jnp.int32, z.shape, 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    idx = (i * rows + row) * chunk + lane
    r = _hash_uniform_u32(seed_ref[0].astype(jnp.uint32), idx)
    q = jnp.minimum(lo + (r < frac).astype(jnp.float32), lm1)
    lev_ref[...] = jnp.where(s > 0, q, 0.0)
    scale_ref[...] = s


@functools.partial(jax.jit, static_argnames=("bits", "chunk", "rows_per_block"))
def quantize(z, seed, bits=8, chunk=CHUNK, rows_per_block=ROWS_PER_BLOCK):
    """Stochastically quantize z (f32[n], n % chunk == 0).

    Args:
      z: f32[n] with n a multiple of `chunk` (pad with ref.pad_to_chunks).
      seed: i32[1] — per-(node, iteration) stream id.

    Returns:
      (levels f32[n] integer-valued in [0, 2^bits-1], scales f32[nchunks])
    """
    n = z.shape[0]
    assert n % chunk == 0, f"pad to chunk multiple first (n={n})"
    nchunks = n // chunk
    zr = _pad_rows(z.reshape(nchunks, chunk), rows_per_block)
    nrows = zr.shape[0]
    grid = nrows // rows_per_block
    levels, scales = pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, chunk=chunk, rows=rows_per_block),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((rows_per_block, chunk), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_block, chunk), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nrows, chunk), jnp.float32),
            jax.ShapeDtypeStruct((nrows, 1), jnp.float32),
        ],
        interpret=True,
    )(zr, jnp.asarray(seed, dtype=jnp.int32).reshape(1))
    return levels.reshape(nrows * chunk)[:n], scales.reshape(nrows)[:nchunks]


def _dequantize_kernel(lev_ref, scale_ref, out_ref, *, bits):
    lev = lev_ref[...]  # (rows, chunk)
    s = scale_ref[...]  # (rows, 1)
    lm1 = jnp.float32(2**bits - 1)
    v = (lev / lm1 * 2.0 - 1.0) * s
    out_ref[...] = jnp.where(s > 0, v, 0.0)


@functools.partial(jax.jit, static_argnames=("bits", "chunk", "rows_per_block"))
def dequantize(levels, scales, bits=8, chunk=CHUNK, rows_per_block=ROWS_PER_BLOCK):
    """Inverse of `quantize`: levels + per-chunk scales -> f32[n]."""
    n = levels.shape[0]
    assert n % chunk == 0
    nchunks = n // chunk
    lr = _pad_rows(levels.reshape(nchunks, chunk), rows_per_block)
    sr = _pad_rows(scales.reshape(nchunks, 1), rows_per_block)
    nrows = lr.shape[0]
    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits),
        grid=(nrows // rows_per_block,),
        in_specs=[
            pl.BlockSpec((rows_per_block, chunk), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_block, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, chunk), jnp.float32),
        interpret=True,
    )(lr, sr)
    return out.reshape(nrows * chunk)[:n]


def quantize_roundtrip(z, seed, bits=8, chunk=CHUNK):
    """C(z) = dequantize(quantize(z)) as one fused jitted graph."""
    levels, scales = quantize(z, seed, bits=bits, chunk=chunk)
    return dequantize(levels, scales, bits=bits, chunk=chunk)
