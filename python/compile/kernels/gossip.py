"""L1 Pallas kernel: fused gossip-average + SGD step.

One pass over the parameter vector computes

    out = w[0] * x + sum_d w[1+d] * neighbors[d] - gamma * grad

which is DCD-PSGD's step 1 (`x_{t+1/2} = Σ_j W_ij x̂_j − γ∇F_i`). Unfused,
this is D+2 reads and D+1 writes of the full vector through HBM; fused it
is D+2 reads and 1 write — the same fusion the paper's implementation does
on GPU with a custom kernel.

§Perf: vectors stream through VMEM in (BLOCK,)-sized tiles of
BLOCK = 32·1024 elements (f32 ⇒ 128 KiB per operand per block — for a
degree-2 ring that is 4 live operands ≈ 512 KiB, comfortably inside a
TPU core's ≈16 MiB VMEM while amortizing grid bookkeeping 32× vs the
naive 1024-element tile). The D-way weighted sum is statically unrolled —
degree is a trace-time constant — so it stays register-resident on the
VPU with no cross-block state.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32 * 1024


def _gossip_kernel(x_ref, nbr_ref, w_ref, gamma_ref, g_ref, out_ref, *, degree):
    x = x_ref[...]  # (1, B)
    acc = w_ref[0] * x
    for d in range(degree):  # static unroll: degree is a trace-time const
        acc = acc + w_ref[1 + d] * nbr_ref[d, :][None, :]
    out_ref[...] = acc - gamma_ref[0] * g_ref[...]


def _pad_tail(v, mult):
    n = v.shape[-1]
    padded = ((n + mult - 1) // mult) * mult
    if padded == n:
        return v
    pad_shape = v.shape[:-1] + (padded - n,)
    return jnp.concatenate([v, jnp.zeros(pad_shape, dtype=v.dtype)], axis=-1)


@functools.partial(jax.jit, static_argnames=("block",))
def gossip_step(x, neighbors, weights, gamma, grad, block=BLOCK):
    """Fused `Σ_j W_ij x̂_j − γ g` over one node's neighborhood.

    Args:
      x: f32[n] local model (any n; padded internally).
      neighbors: f32[d, n] neighbor replicas (row per neighbor).
      weights: f32[1 + d] mixing weights, self weight first.
      gamma: f32[1] step size.
      grad: f32[n] stochastic gradient.

    Returns:
      f32[n] = x_{t+1/2}.
    """
    n = x.shape[0]
    degree = neighbors.shape[0]
    assert weights.shape[0] == degree + 1
    xp = _pad_tail(x, block)
    nbrp = _pad_tail(neighbors, block)
    gp = _pad_tail(grad, block)
    npad = xp.shape[0]
    nblocks = npad // block
    out = pl.pallas_call(
        functools.partial(_gossip_kernel, degree=degree),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (i, 0)),
            pl.BlockSpec((degree, block), lambda i: (0, i)),
            pl.BlockSpec((degree + 1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks, block), jnp.float32),
        interpret=True,
    )(
        xp.reshape(nblocks, block),
        nbrp,
        weights,
        jnp.asarray(gamma, dtype=jnp.float32).reshape(1),
        gp.reshape(nblocks, block),
    )
    return out.reshape(npad)[:n]
