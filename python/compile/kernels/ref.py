"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has a reference implementation here
written with plain jax.numpy ops — no pallas, no custom control flow. The
pytest suite asserts the kernels match these exactly (same hash-based
randomness), and the hypothesis sweeps run both over random shapes.
"""

import jax.numpy as jnp
import numpy as np

# Chunk size shared with the rust codec (compression::quantize::DEFAULT_CHUNK)
# and the Pallas kernel — one scale per 1024 elements.
CHUNK = 1024


def hash_uniform(seed, idx):
    """Counter-based uniform in [0,1): murmur3-style finalizer over
    (seed, element index). Deterministic, stateless, identical in the
    Pallas kernel, this oracle, and the tests.
    """
    x = (idx.astype(jnp.uint32) * jnp.uint32(2654435761)) ^ seed.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    # Top 24 bits -> [0, 1) with full f32 precision.
    return (x >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def pad_to_chunks(z, chunk=CHUNK):
    """Zero-pad a 1-D vector to a multiple of `chunk`."""
    n = z.shape[0]
    padded = ((n + chunk - 1) // chunk) * chunk
    if padded == n:
        return z
    return jnp.concatenate([z, jnp.zeros(padded - n, dtype=z.dtype)])


def quantize_ref(z, seed, bits=8, chunk=CHUNK):
    """Stochastic uniform quantization (paper footnote 1), reference.

    Args:
      z: f32[n], n a multiple of `chunk` (use pad_to_chunks first).
      seed: scalar int32/uint32.
      bits: levels = 2**bits.

    Returns:
      levels: f32[n] integer-valued in [0, 2**bits - 1]
      scales: f32[nchunks] per-chunk max-abs
    """
    n = z.shape[0]
    assert n % chunk == 0, f"pad to chunk multiple first (n={n})"
    nchunks = n // chunk
    zr = z.reshape(nchunks, chunk)
    scales = jnp.max(jnp.abs(zr), axis=1)
    lm1 = jnp.float32(2**bits - 1)
    safe = jnp.where(scales > 0, scales, 1.0)
    u = (zr / safe[:, None] + 1.0) * 0.5 * lm1
    u = jnp.clip(u, 0.0, lm1)
    lo = jnp.floor(u)
    frac = u - lo
    idx = jnp.arange(n, dtype=jnp.int32).reshape(nchunks, chunk)
    r = hash_uniform(jnp.asarray(seed), idx)
    q = lo + (r < frac).astype(jnp.float32)
    q = jnp.minimum(q, lm1)
    q = jnp.where(scales[:, None] > 0, q, 0.0)
    return q.reshape(n), scales


def dequantize_ref(levels, scales, bits=8, chunk=CHUNK):
    """Inverse map: level -> (q/(L-1)*2 - 1) * scale."""
    n = levels.shape[0]
    nchunks = n // chunk
    lm1 = jnp.float32(2**bits - 1)
    lr = levels.reshape(nchunks, chunk)
    out = (lr / lm1 * 2.0 - 1.0) * scales[:, None]
    out = jnp.where(scales[:, None] > 0, out, 0.0)
    return out.reshape(n)


def gossip_step_ref(x, neighbors, weights, gamma, grad):
    """Fused gossip-average + SGD step, reference.

    out = weights[0] * x + sum_d weights[1+d] * neighbors[d] - gamma * grad

    Args:
      x: f32[n] local model
      neighbors: f32[d, n] neighbor replicas
      weights: f32[1 + d] mixing weights (self first)
      gamma: f32[] or f32[1] step size
      grad: f32[n] stochastic gradient
    """
    mixed = weights[0] * x + jnp.einsum("d,dn->n", weights[1:], neighbors)
    return mixed - jnp.reshape(gamma, ()) * grad


def quantize_roundtrip_ref(z, seed, bits=8, chunk=CHUNK):
    """C(z) = dequantize(quantize(z)) — the full operator."""
    levels, scales = quantize_ref(z, seed, bits=bits, chunk=chunk)
    return dequantize_ref(levels, scales, bits=bits, chunk=chunk)


def numpy_hash_uniform(seed, idx):
    """NumPy twin of hash_uniform, for host-side test assertions."""
    x = (idx.astype(np.uint32) * np.uint32(2654435761)) ^ np.uint32(seed)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return (x >> np.uint32(8)).astype(np.float32) / np.float32(1 << 24)
