"""AOT lowering: jax/pallas graphs -> HLO *text* artifacts for the rust
PJRT runtime.

Interchange is HLO text, NOT serialized HloModuleProto: jax >= 0.5 emits
64-bit instruction ids that the crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (all shapes baked at lower time, recorded in manifest.json):

  grad_step.hlo.txt   (params f32[N], tokens i32[B,S+1]) -> (loss, grads)
  dcd_step.hlo.txt    the fused DCD-PSGD local step (gossip + fwd/bwd +
                      Pallas quantization) — one PJRT call per node/iter
  quantize8.hlo.txt   (z f32[Np], seed i32[1]) -> (levels, scales)
  gossip.hlo.txt      (x, neighbors, weights, gamma, grad) -> x_half

Usage: python -m compile.aot --out-dir ../artifacts [--preset small|base|large]
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import gossip as gossip_k
from .kernels import quantize as quantize_k
from .kernels.ref import CHUNK

PRESETS = {
    # ~0.8M params: CI-speed e2e training on CPU.
    "small": M.ModelConfig(vocab=256, d_model=128, n_layers=2, n_heads=4, d_ff=512, seq_len=64),
    # ~3.3M params: the default e2e driver.
    "base": M.ModelConfig(vocab=256, d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq_len=128),
    # ~110M params: GPT-2-small-class; for real accelerators.
    "large": M.ModelConfig(vocab=50257, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq_len=512),
}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifacts(cfg: M.ModelConfig, batch: int, degree: int, bits: int, out_dir: str):
    """Lower every artifact and write the manifest. Returns the manifest."""
    os.makedirs(out_dir, exist_ok=True)
    n = M.param_count(cfg)
    np_ = M.padded_dim(cfg)
    nchunks = np_ // CHUNK

    f32, i32 = jnp.float32, jnp.int32
    spec = jax.ShapeDtypeStruct
    tokens_spec = spec((batch, cfg.seq_len + 1), i32)

    artifacts = {}

    def emit(name, fn, *args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [list(a.shape) for a in args],
            "hlo_bytes": len(text),
        }
        print(f"  {name}: {len(text)} chars -> {path}")

    emit(
        "grad_step",
        functools.partial(M.grad_step, cfg),
        spec((n,), f32),
        tokens_spec,
    )
    emit(
        "dcd_step",
        functools.partial(M.dcd_fused_step, cfg, bits=bits),
        spec((np_,), f32),
        spec((degree, np_), f32),
        spec((degree + 1,), f32),
        spec((1,), f32),
        tokens_spec,
        spec((1,), i32),
    )
    emit(
        "quantize8",
        functools.partial(quantize_k.quantize, bits=bits),
        spec((np_,), f32),
        spec((1,), i32),
    )
    emit(
        "gossip",
        gossip_k.gossip_step,
        spec((np_,), f32),
        spec((degree, np_), f32),
        spec((degree + 1,), f32),
        spec((1,), f32),
        spec((np_,), f32),
    )

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
        },
        "param_count": n,
        "padded_dim": np_,
        "nchunks": nchunks,
        "chunk": CHUNK,
        "batch": batch,
        "degree": degree,
        "bits": bits,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def init_params_file(cfg: M.ModelConfig, seed: int, out_dir: str):
    """Write the shared initial flat parameter vector (f32 LE bytes) so
    every rust worker starts from the same x_1."""
    flat = M.init_flat(cfg, seed)
    path = os.path.join(out_dir, "init_params.f32")
    import numpy as np

    np.asarray(flat, dtype="<f4").tofile(path)
    print(f"  init_params: {flat.shape[0]} f32 -> {path}")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--preset", default="small", choices=sorted(PRESETS))
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--degree", type=int, default=2, help="gossip degree (ring=2)")
    p.add_argument("--bits", type=int, default=8)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = PRESETS[args.preset]
    print(f"lowering preset={args.preset} ({M.param_count(cfg)} params) -> {args.out_dir}")
    lower_artifacts(cfg, args.batch, args.degree, args.bits, args.out_dir)
    init_params_file(cfg, args.seed, args.out_dir)
    print("done")


if __name__ == "__main__":
    main()
