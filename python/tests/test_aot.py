"""AOT path: artifacts lower to valid HLO text and the manifest is
consistent with the model configuration."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels.ref import CHUNK

TINY = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_artifacts(TINY, batch=2, degree=2, bits=8, out_dir=out)
    aot.init_params_file(TINY, seed=0, out_dir=out)
    return out, manifest


def test_all_artifacts_written(built):
    out, manifest = built
    for name in ["grad_step", "dcd_step", "quantize8", "gossip"]:
        assert name in manifest["artifacts"]
        path = os.path.join(out, manifest["artifacts"][name]["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_consistency(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        reloaded = json.load(f)
    assert reloaded == manifest
    assert manifest["param_count"] == M.param_count(TINY)
    assert manifest["padded_dim"] % CHUNK == 0
    assert manifest["padded_dim"] >= manifest["param_count"]
    assert manifest["nchunks"] == manifest["padded_dim"] // CHUNK
    assert manifest["model"]["d_model"] == TINY.d_model


def test_grad_step_input_shapes_recorded(built):
    _, manifest = built
    ins = manifest["artifacts"]["grad_step"]["inputs"]
    assert ins[0] == [M.param_count(TINY)]
    assert ins[1] == [2, TINY.seq_len + 1]


def test_init_params_file_round_trips(built):
    out, manifest = built
    raw = np.fromfile(os.path.join(out, "init_params.f32"), dtype="<f4")
    assert raw.shape[0] == manifest["param_count"]
    flat = np.asarray(M.init_flat(TINY, 0))
    np.testing.assert_array_equal(raw, flat)


def test_hlo_has_no_serialized_proto_markers(built):
    """Guard: we must ship text, not binary proto (xla_extension 0.5.1
    rejects jax>=0.5 protos; see aot.py docstring)."""
    out, manifest = built
    for art in manifest["artifacts"].values():
        with open(os.path.join(out, art["file"]), "rb") as f:
            head = f.read(64)
        assert head.decode("utf-8", errors="strict").startswith("HloModule")


def test_presets_are_ordered_by_size():
    small = M.param_count(aot.PRESETS["small"])
    base = M.param_count(aot.PRESETS["base"])
    large = M.param_count(aot.PRESETS["large"])
    assert small < base < large
    assert large > 80_000_000  # ~GPT-2-small class
