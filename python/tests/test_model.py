"""L2 correctness: transformer shapes, loss/grad sanity, fused DCD step."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels.ref import CHUNK

TINY = M.ModelConfig(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


@pytest.fixture(scope="module")
def flat():
    return M.init_flat(TINY, 0)


class TestShapes:
    def test_param_count_matches_shapes(self, flat):
        assert flat.shape == (M.param_count(TINY),)
        total = sum(int(np.prod(s)) for _, s in M.param_shapes(TINY))
        assert total == M.param_count(TINY)

    def test_unflatten_covers_everything(self, flat):
        params = M.unflatten(TINY, flat)
        assert set(params) == {name for name, _ in M.param_shapes(TINY)}
        for name, shape in M.param_shapes(TINY):
            assert params[name].shape == shape

    def test_forward_logits_shape(self, flat):
        toks = M.synthetic_tokens(TINY, 3, seed=1)
        params = M.unflatten(TINY, flat)
        logits = M.forward(TINY, params, toks[:, :-1])
        assert logits.shape == (3, TINY.seq_len, TINY.vocab)

    def test_padded_dim_is_chunk_multiple(self):
        assert M.padded_dim(TINY) % CHUNK == 0
        assert M.padded_dim(TINY) >= M.param_count(TINY)


class TestLossAndGrad:
    def test_initial_loss_near_log_vocab(self, flat):
        toks = M.synthetic_tokens(TINY, 4, seed=2)
        loss = M.loss_fn(TINY, flat, toks)
        assert abs(float(loss) - np.log(TINY.vocab)) < 0.7

    def test_grad_nonzero_and_finite(self, flat):
        toks = M.synthetic_tokens(TINY, 2, seed=3)
        loss, g = M.grad_step(TINY, flat, toks)
        assert np.isfinite(float(loss))
        gn = float(jnp.linalg.norm(g))
        assert np.isfinite(gn) and gn > 1e-3

    def test_grad_matches_finite_difference(self, flat):
        toks = M.synthetic_tokens(TINY, 2, seed=4)
        _, g = M.grad_step(TINY, flat, toks)
        g = np.asarray(g, dtype=np.float64)
        rs = np.random.RandomState(0)
        idxs = rs.choice(flat.shape[0], size=10, replace=False)
        eps = 1e-3
        for i in idxs:
            e = np.zeros(flat.shape[0], dtype=np.float32)
            e[i] = eps
            lp = float(M.loss_fn(TINY, flat + jnp.asarray(e), toks))
            lm = float(M.loss_fn(TINY, flat - jnp.asarray(e), toks))
            fd = (lp - lm) / (2 * eps)
            assert abs(fd - g[i]) < 5e-2 * (1 + abs(fd)), f"coord {i}: {g[i]} vs {fd}"

    def test_sgd_reduces_loss(self, flat):
        toks = M.synthetic_tokens(TINY, 8, seed=5)
        x = flat
        step = jax.jit(functools.partial(M.grad_step, TINY))
        l0, _ = step(x, toks)
        for _ in range(30):
            _, g = step(x, toks)
            x = x - 0.5 * g
        l1, _ = step(x, toks)
        assert float(l1) < float(l0) - 0.3, f"{float(l0)} -> {float(l1)}"

    def test_synthetic_tokens_learnable_structure(self):
        # Two nodes get different transition params — heterogeneity knob.
        a = M.synthetic_tokens(TINY, 2, seed=1, node=0)
        b = M.synthetic_tokens(TINY, 2, seed=1, node=1)
        assert a.shape == b.shape == (2, TINY.seq_len + 1)
        assert not np.array_equal(np.asarray(a), np.asarray(b))
        assert int(a.max()) < TINY.vocab and int(a.min()) >= 0


class TestFusedDcdStep:
    def test_fused_matches_composition(self, flat):
        """dcd_fused_step ≡ grad_step → gossip kernel → quantize kernel."""
        from compile.kernels import gossip as GK
        from compile.kernels import quantize as QK

        npad = M.padded_dim(TINY)
        n = M.param_count(TINY)
        x = jnp.concatenate([flat, jnp.zeros(npad - n, dtype=jnp.float32)])
        rs = np.random.RandomState(1)
        nbrs = jnp.asarray(rs.randn(2, npad).astype(np.float32) * 0.01 + np.asarray(x))
        w = jnp.asarray(np.array([1 / 3, 1 / 3, 1 / 3], dtype=np.float32))
        gamma = jnp.asarray([0.1], dtype=jnp.float32)
        toks = M.synthetic_tokens(TINY, 2, seed=6)
        seed = jnp.asarray([99], dtype=jnp.int32)

        loss_f, x_new_f, lev_f, sc_f = M.dcd_fused_step(TINY, x, nbrs, w, gamma, toks, seed)

        loss_c, g = M.grad_step(TINY, x[:n], toks)
        g_pad = jnp.concatenate([g, jnp.zeros(npad - n, dtype=jnp.float32)])
        x_half = GK.gossip_step(x, nbrs, w, gamma, g_pad)
        lev_c, sc_c = QK.quantize(x_half - x, seed, bits=8)
        cz = QK.dequantize(lev_c, sc_c, bits=8)
        x_new_c = x + cz

        assert float(loss_f) == pytest.approx(float(loss_c), abs=1e-6)
        np.testing.assert_array_equal(np.asarray(lev_f), np.asarray(lev_c))
        np.testing.assert_allclose(np.asarray(x_new_f), np.asarray(x_new_c), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(sc_f), np.asarray(sc_c))

    def test_fused_step_converges_decentralized(self, flat):
        """4 tiny nodes on a ring, 25 fused DCD steps: loss drops."""
        n_nodes = 4
        npad = M.padded_dim(TINY)
        n = M.param_count(TINY)
        pad = jnp.zeros(npad - n, dtype=jnp.float32)
        xs = [jnp.concatenate([flat, pad]) for _ in range(n_nodes)]
        w = jnp.asarray(np.array([1 / 3, 1 / 3, 1 / 3], dtype=np.float32))
        gamma = jnp.asarray([0.3], dtype=jnp.float32)
        step = jax.jit(functools.partial(M.dcd_fused_step, TINY, bits=8))

        first = last = None
        for t in range(25):
            new_xs = []
            losses = []
            for i in range(n_nodes):
                left, right = xs[(i - 1) % n_nodes], xs[(i + 1) % n_nodes]
                toks = M.synthetic_tokens(TINY, 4, seed=100 + t, node=i)
                loss, x_new, _, _ = step(
                    xs[i],
                    jnp.stack([left, right]),
                    w,
                    gamma,
                    toks,
                    jnp.asarray([t * n_nodes + i], dtype=jnp.int32),
                )
                new_xs.append(x_new)
                losses.append(float(loss))
            xs = new_xs
            mean_loss = float(np.mean(losses))
            if t == 0:
                first = mean_loss
            last = mean_loss
        assert last < first - 0.2, f"{first} -> {last}"
