"""L1 correctness: Pallas quantization kernel vs the pure-jnp oracle.

The CORE correctness signal of the compile path: the kernel must agree
with ref.py bit-for-bit on levels (same hash RNG) and to f32 round-off on
dequantized values, across a hypothesis sweep of shapes, bits and input
distributions.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quantize as Q
from compile.kernels import ref

CHUNK = ref.CHUNK


def _rand(n, seed, scale=1.0, dtype=np.float32):
    return (np.random.RandomState(seed).randn(n) * scale).astype(dtype)


class TestAgainstRef:
    @pytest.mark.parametrize("bits", [1, 2, 4, 8])
    @pytest.mark.parametrize("nchunks", [1, 3])
    def test_levels_match_ref_exactly(self, bits, nchunks):
        z = jnp.asarray(_rand(nchunks * CHUNK, seed=bits * 10 + nchunks))
        lev, sc = Q.quantize(z, 42, bits=bits)
        lev_r, sc_r = ref.quantize_ref(z, 42, bits=bits)
        np.testing.assert_array_equal(np.asarray(lev), np.asarray(lev_r))
        np.testing.assert_allclose(np.asarray(sc), np.asarray(sc_r), rtol=0)

    @pytest.mark.parametrize("bits", [2, 8])
    def test_dequantize_matches_ref(self, bits):
        z = jnp.asarray(_rand(2 * CHUNK, seed=7))
        lev, sc = Q.quantize(z, 1, bits=bits)
        out = Q.dequantize(lev, sc, bits=bits)
        out_r = ref.dequantize_ref(lev, sc, bits=bits)
        np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=1e-5)

    def test_different_seeds_different_rounding(self):
        z = jnp.asarray(_rand(CHUNK, seed=3))
        lev_a, _ = Q.quantize(z, 1, bits=8)
        lev_b, _ = Q.quantize(z, 2, bits=8)
        assert not np.array_equal(np.asarray(lev_a), np.asarray(lev_b))

    def test_same_seed_deterministic(self):
        z = jnp.asarray(_rand(CHUNK, seed=4))
        lev_a, sc_a = Q.quantize(z, 9, bits=4)
        lev_b, sc_b = Q.quantize(z, 9, bits=4)
        np.testing.assert_array_equal(np.asarray(lev_a), np.asarray(lev_b))
        np.testing.assert_array_equal(np.asarray(sc_a), np.asarray(sc_b))


class TestOperatorProperties:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_error_bounded_by_step(self, bits):
        z = jnp.asarray(_rand(2 * CHUNK, seed=bits))
        out = np.asarray(Q.quantize_roundtrip(z, 5, bits=bits))
        zn = np.asarray(z)
        scales = np.abs(zn.reshape(2, CHUNK)).max(axis=1)
        step = 2.0 * scales[:, None] / (2**bits - 1)
        err = np.abs(out.reshape(2, CHUNK) - zn.reshape(2, CHUNK))
        assert (err <= step + 1e-5).all()

    def test_unbiased_over_seeds(self):
        z = jnp.asarray(_rand(CHUNK, seed=11, scale=0.5))
        acc = np.zeros(CHUNK, dtype=np.float64)
        trials = 600
        for s in range(trials):
            acc += np.asarray(Q.quantize_roundtrip(z, s, bits=4))
        mean = acc / trials
        scale = float(np.abs(np.asarray(z)).max())
        step = 2.0 * scale / 15
        # Std of mean ≈ step/√(4·trials); allow 5 sigma.
        tol = 5 * step / np.sqrt(4 * trials)
        np.testing.assert_allclose(mean, np.asarray(z), atol=tol)

    def test_zero_chunk_stays_zero(self):
        z = jnp.zeros(2 * CHUNK, dtype=jnp.float32)
        lev, sc = Q.quantize(z, 3, bits=8)
        assert np.all(np.asarray(sc) == 0)
        out = np.asarray(Q.dequantize(lev, sc, bits=8))
        assert np.all(out == 0)

    def test_mixed_zero_and_live_chunks(self):
        z = np.zeros(3 * CHUNK, dtype=np.float32)
        z[CHUNK : 2 * CHUNK] = _rand(CHUNK, seed=12)
        out = np.asarray(Q.quantize_roundtrip(jnp.asarray(z), 8, bits=8))
        assert np.all(out[:CHUNK] == 0)
        assert np.all(out[2 * CHUNK :] == 0)
        assert np.abs(out[CHUNK : 2 * CHUNK] - z[CHUNK : 2 * CHUNK]).max() < 0.05

    def test_one_bit_levels_are_binary(self):
        z = jnp.asarray(_rand(CHUNK, seed=13))
        lev, _ = Q.quantize(z, 2, bits=1)
        assert set(np.unique(np.asarray(lev))) <= {0.0, 1.0}


class TestHypothesisSweep:
    """Shape/bits/distribution sweep: kernel ≡ oracle everywhere."""

    @settings(max_examples=25, deadline=None)
    @given(
        nchunks=st.integers(min_value=1, max_value=4),
        bits=st.sampled_from([1, 2, 3, 4, 6, 8]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        scale=st.sampled_from([1e-3, 1.0, 1e3]),
    )
    def test_kernel_equals_oracle(self, nchunks, bits, seed, scale):
        z = jnp.asarray(_rand(nchunks * CHUNK, seed=seed % 1000, scale=scale))
        lev, sc = Q.quantize(z, seed, bits=bits)
        lev_r, sc_r = ref.quantize_ref(z, seed, bits=bits)
        np.testing.assert_array_equal(np.asarray(lev), np.asarray(lev_r))
        np.testing.assert_array_equal(np.asarray(sc), np.asarray(sc_r))
        assert np.asarray(lev).max() <= 2**bits - 1

    @settings(max_examples=15, deadline=None)
    @given(
        n_extra=st.integers(min_value=0, max_value=CHUNK - 1),
        seed=st.integers(min_value=0, max_value=10**6),
    )
    def test_padding_round_trip(self, n_extra, seed):
        """pad_to_chunks + quantize handles every residual length."""
        n = CHUNK + n_extra
        z = jnp.asarray(_rand(n, seed=seed % 997))
        zp = ref.pad_to_chunks(z)
        assert zp.shape[0] % CHUNK == 0
        out = np.asarray(Q.quantize_roundtrip(zp, seed, bits=8))[:n]
        scale = float(np.abs(np.asarray(zp)).max())
        assert np.abs(out - np.asarray(z)).max() <= 2 * scale / 255 + 1e-5

    @settings(max_examples=10, deadline=None)
    @given(dtype=st.sampled_from([np.float64, np.float16]))
    def test_dtype_upcast(self, dtype):
        """Non-f32 inputs are accepted after an explicit cast (the kernel
        contract is f32; the sweep verifies the cast path loses nothing
        beyond the dtype's own precision)."""
        z64 = _rand(CHUNK, seed=21, dtype=np.float32).astype(dtype)
        z = jnp.asarray(z64.astype(np.float32))
        lev, sc = Q.quantize(z, 2, bits=8)
        lev_r, sc_r = ref.quantize_ref(z, 2, bits=8)
        np.testing.assert_array_equal(np.asarray(lev), np.asarray(lev_r))


class TestHashRng:
    def test_hash_uniform_matches_numpy_twin(self):
        idx = jnp.arange(4096, dtype=jnp.int32)
        a = np.asarray(ref.hash_uniform(jnp.asarray(77), idx))
        b = ref.numpy_hash_uniform(77, np.arange(4096))
        np.testing.assert_array_equal(a, b)

    def test_hash_uniform_distribution(self):
        idx = jnp.arange(1 << 16, dtype=jnp.int32)
        u = np.asarray(ref.hash_uniform(jnp.asarray(123), idx))
        assert 0.0 <= u.min() and u.max() < 1.0
        assert abs(u.mean() - 0.5) < 0.01
        # Roughly uniform deciles.
        hist, _ = np.histogram(u, bins=10, range=(0, 1))
        assert (np.abs(hist - len(u) / 10) < 0.05 * len(u) / 10 + 100).all()
