"""L1 correctness: fused gossip+SGD Pallas kernel vs the jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import gossip as G
from compile.kernels import ref

BLOCK = G.BLOCK


def _rand(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


def test_matches_ref_ring_degree2():
    n = 2 * BLOCK
    x = jnp.asarray(_rand((n,), 0))
    nbrs = jnp.asarray(_rand((2, n), 1))
    w = jnp.asarray(np.array([1 / 3, 1 / 3, 1 / 3], dtype=np.float32))
    g = jnp.asarray(_rand((n,), 2))
    out = G.gossip_step(x, nbrs, w, 0.1, g)
    out_r = ref.gossip_step_ref(x, nbrs, w, 0.1, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=1e-5)


def test_zero_gamma_is_pure_gossip():
    n = BLOCK
    x = jnp.asarray(_rand((n,), 3))
    nbrs = jnp.asarray(_rand((2, n), 4))
    w = jnp.asarray(np.array([0.5, 0.25, 0.25], dtype=np.float32))
    g = jnp.asarray(_rand((n,), 5) * 1e6)  # gradient must be ignored
    out = np.asarray(G.gossip_step(x, nbrs, w, 0.0, g))
    expect = 0.5 * np.asarray(x) + 0.25 * np.asarray(nbrs[0]) + 0.25 * np.asarray(nbrs[1])
    np.testing.assert_allclose(out, expect, atol=1e-4)


def test_identity_weights_recover_sgd():
    n = BLOCK
    x = jnp.asarray(_rand((n,), 6))
    nbrs = jnp.zeros((2, n), dtype=jnp.float32)
    w = jnp.asarray(np.array([1.0, 0.0, 0.0], dtype=np.float32))
    g = jnp.asarray(_rand((n,), 7))
    out = np.asarray(G.gossip_step(x, nbrs, w, 0.2, g))
    np.testing.assert_allclose(out, np.asarray(x) - 0.2 * np.asarray(g), atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    nblocks=st.integers(min_value=1, max_value=3),
    degree=st.integers(min_value=1, max_value=4),
    gamma=st.sampled_from([0.0, 0.01, 0.5]),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_hypothesis_sweep(nblocks, degree, gamma, seed):
    n = nblocks * BLOCK
    rs = seed % 991
    x = jnp.asarray(_rand((n,), rs))
    nbrs = jnp.asarray(_rand((degree, n), rs + 1))
    raw = np.abs(_rand((degree + 1,), rs + 2)) + 0.1
    w = jnp.asarray((raw / raw.sum()).astype(np.float32))
    g = jnp.asarray(_rand((n,), rs + 3))
    out = G.gossip_step(x, nbrs, w, gamma, g)
    out_r = ref.gossip_step_ref(x, nbrs, w, gamma, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_r), atol=1e-4)


def test_doubly_stochastic_preserves_constant_vectors():
    # If x and all neighbors equal c·1 and weights sum to 1, the mixed
    # part stays c·1 (the consensus fixed point).
    n = BLOCK
    c = 0.7
    x = jnp.full((n,), c, dtype=jnp.float32)
    nbrs = jnp.full((2, n), c, dtype=jnp.float32)
    w = jnp.asarray(np.array([1 / 3, 1 / 3, 1 / 3], dtype=np.float32))
    g = jnp.zeros((n,), dtype=jnp.float32)
    out = np.asarray(G.gossip_step(x, nbrs, w, 0.1, g))
    np.testing.assert_allclose(out, c, atol=1e-6)
