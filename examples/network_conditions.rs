//! Network-conditions explorer: the paper's §5.3 landscape (Fig. 3) plus
//! a custom-condition probe, on either time model.
//!
//!   cargo run --release --example network_conditions
//!   cargo run --release --example network_conditions -- \
//!       --bandwidth-mbps 25 --latency-ms 2 --nodes 64
//!
//! Prints epoch times of Allreduce fp32 / decentralized fp32 /
//! decentralized 8-bit over the ResNet-20 testbed constants, reports which
//! implementation wins the custom condition, then cross-checks the closed
//! form against *measured* virtual time from the discrete-event backend
//! (`--nodes` scales the measured ring, default 8, try 64).

use decomp::experiments::fig3::{self, epoch_times, sim_sweep_points};
use decomp::metrics::{fmt_bytes, fmt_secs, Table};
use decomp::network::cost::NetworkModel;
use decomp::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();

    // The full Fig. 3 sweep.
    for t in fig3::run(false) {
        t.print();
        println!();
    }

    // Custom probe.
    let bw = args.f64("bandwidth-mbps", 25.0) * 1e6;
    let lat = args.f64("latency-ms", 2.0) * 1e-3;
    let net = NetworkModel::new(bw, lat);
    let (ar, d32, d8) = epoch_times(&net, 8);
    let mut t = Table::new(
        &format!(
            "custom condition: {:.0} Mbps, {:.2} ms (n=8 ring, ResNet-20 payload)",
            bw / 1e6,
            lat * 1e3
        ),
        &["implementation", "epoch_time", "vs_best"],
    );
    let best = ar.min(d32).min(d8);
    for (name, v) in [
        ("allreduce_fp32", ar),
        ("decentralized_fp32", d32),
        ("decentralized_8bit", d8),
    ] {
        t.row(vec![
            name.into(),
            fmt_secs(v),
            format!("{:.2}x", v / best),
        ]);
    }
    t.print();

    let winner = if d8 <= best {
        "decentralized_8bit"
    } else if d32 <= best {
        "decentralized_fp32"
    } else {
        "allreduce_fp32"
    };
    println!("\nwinner: {winner} (paper §5.3: compression+decentralization wins when both bandwidth and latency are bad)");

    // Measured cross-check: run real compressed-gossip iterations on the
    // discrete-event backend under the same condition and compare its
    // virtual per-iteration time to the closed form. The sim ring scales
    // where threads cannot — try --nodes 64.
    let n_sim = args.usize("nodes", 8);
    let mut mt = Table::new(
        &format!("measured on sim backend: ring n={n_sim}, dim=1024, same condition"),
        &["algo", "virtual_s_per_iter", "payload_per_node_iter", "frame_overhead"],
    );
    for p in sim_sweep_points(&[n_sim], 3, net) {
        mt.row(vec![
            p.algo,
            fmt_secs(p.virtual_s_per_iter),
            fmt_bytes(p.payload_per_node_iter),
            format!("{:.3}%", p.frame_overhead * 100.0),
        ]);
    }
    mt.print();
    println!(
        "\n(The measured rows include NIC serialization and frame headers the\n\
         closed form ignores; run `decomp train --backend sim` for full traces.)"
    );
    Ok(())
}
