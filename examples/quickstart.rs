//! Quickstart: train the same decentralized workload with every
//! algorithm in the library and compare convergence + bytes on the wire.
//!
//!   cargo run --release --example quickstart
//!
//! 8 workers on a ring, heterogeneous logistic-regression shards (the
//! CIFAR substitute — see DESIGN.md §5), 500 synchronous iterations.
//! Expected output: DCD/ECD at 8 bits match full-precision convergence
//! while sending ~4x fewer bytes; the naive scheme stalls; CHOCO with the
//! biased 1-bit sign compressor still tracks full precision at ~1/32 the
//! bytes.

use decomp::algorithms::{self, RunOpts};
use decomp::coordinator::TrainConfig;
use decomp::metrics::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let base = TrainConfig {
        n_nodes: 8,
        iters: 500,
        gamma: 0.05,
        model: "logistic".into(),
        dim: 64,
        ..Default::default()
    };

    let mut table = Table::new(
        "quickstart: 8-node ring, heterogeneous logistic regression, 500 iters",
        &["algorithm", "compressor", "final f(x̄)", "consensus", "bytes/node/iter"],
    );

    for (algo, comp, eta) in [
        ("allreduce", "fp32", 1.0f32),
        ("dpsgd", "fp32", 1.0),
        ("dcd", "q8", 1.0),
        ("ecd", "q8", 1.0),
        ("dcd", "q4", 1.0),
        ("naive", "q8", 1.0),
        ("choco", "sign", 0.4),
        ("choco", "lowrank_r2", 0.4),
        ("deepsqueeze", "q4", 1.0),
    ] {
        let cfg = TrainConfig {
            algo: algo.into(),
            compressor: comp.into(),
            eta,
            ..base.clone()
        };
        let algo_cfg = cfg.build_algo_config()?;
        let (mut models, x0) = cfg.build_models()?;
        let mut a = algorithms::from_name(algo, algo_cfg, &x0, cfg.n_nodes)
            .ok_or_else(|| anyhow::anyhow!("unknown algorithm"))?;
        let opts = RunOpts {
            iters: cfg.iters,
            gamma: cfg.gamma,
            eval_every: cfg.iters,
            ..Default::default()
        };
        let trace = algorithms::run_training(a.as_mut(), &mut models, &opts);
        let last = trace.points.last().unwrap();
        table.row(vec![
            algo.into(),
            comp.into(),
            format!("{:.4}", last.global_loss),
            format!("{:.2e}", last.consensus),
            fmt_bytes(last.bytes_sent as f64 / (cfg.iters * cfg.n_nodes) as f64),
        ]);
    }
    table.print();
    println!("\nNote: q8 rows should match fp32 convergence at ~1/4 the bytes;");
    println!("`naive` demonstrates why unmodified compression fails (Fig. 1);");
    println!("`choco sign` ships 1 bit/coordinate — error feedback makes the");
    println!("biased operator sound where dcd/ecd would reject it;");
    println!("`choco lowrank_r2` is PowerGossip: warm-started rank-2 factors");
    println!("of the 8x8 parameter fold (see `decomp lowranksweep` for the");
    println!("large-matrix regime where low rank is extreme compression).");
    Ok(())
}
