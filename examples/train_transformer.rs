//! End-to-end driver: decentralized training of the JAX transformer LM
//! with DCD-PSGD 8-bit compression, through the full three-layer stack.
//!
//! Per node and iteration this makes ONE PJRT call into the fused
//! `dcd_step` artifact (L2 fwd/bwd + L1 Pallas gossip & quantization
//! kernels lowered into a single HLO module), then routes the compressed
//! wire payload (levels + scales — exactly what would cross the network)
//! to the ring neighbors. Python is not running: the artifacts were
//! AOT-lowered by `make artifacts`.
//!
//! Usage:
//!   cargo run --release --example train_transformer -- \
//!       [--steps 300] [--nodes 4] [--gamma 0.25] [--log-every 10]
//!
//! Requires `make artifacts` (PRESET=small by default; see Makefile).

use decomp::compression::{Compressor, StochasticQuantizer};
use decomp::metrics::{fmt_bytes, Table};
use decomp::runtime::{PjrtEngine, TokenSampler};
use decomp::util::cli::Args;
use decomp::util::rng::Pcg64;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let steps = args.usize("steps", 300);
    let n_nodes = args.usize("nodes", 4);
    let gamma = args.f64("gamma", 0.25) as f32;
    let log_every = args.usize("log-every", 10);

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts/ not built — run `make artifacts` first"
    );
    let engine = Arc::new(PjrtEngine::load(&dir)?);
    let m = engine.manifest.clone();
    anyhow::ensure!(
        m.degree == 2,
        "artifacts were lowered for gossip degree {}, ring needs 2",
        m.degree
    );
    println!(
        "e2e: {} params ({} padded), vocab {}, seq {}, batch {} | {} nodes ring, DCD q{}, gamma {}",
        m.param_count, m.padded_dim, m.vocab, m.seq_len, m.batch, n_nodes, m.bits, gamma
    );

    // Shared x₁ for every node (paper's requirement), zero-padded.
    let init = m.load_init_params()?;
    let mut xs: Vec<Vec<f32>> = (0..n_nodes)
        .map(|_| {
            let mut x = vec![0.0f32; m.padded_dim];
            x[..m.param_count].copy_from_slice(&init);
            x
        })
        .collect();

    // Ring mixing: uniform 1/3 weights (self, left, right).
    let weights = vec![1.0f32 / 3.0; 3];
    let samplers: Vec<TokenSampler> = (0..n_nodes)
        .map(|i| TokenSampler {
            vocab: m.vocab as i32,
            seq_len: m.seq_len,
            batch: m.batch,
            node: i as i32,
        })
        .collect();
    let mut rngs: Vec<Pcg64> = (0..n_nodes)
        .map(|i| Pcg64::new(0xe2e, 0x6000 + i as u64))
        .collect();

    // Wire accounting: what the compressed message would cost vs fp32.
    let q8_wire = StochasticQuantizer::new(m.bits).wire_bytes(m.padded_dim);
    let fp_wire = 4 * m.padded_dim;
    let mut bytes_sent = 0u64;

    let mut table = Table::new(
        "DCD-PSGD 8-bit decentralized transformer training (fused PJRT step)",
        &["step", "mean_loss", "consensus", "wire_sent"],
    );
    let t0 = std::time::Instant::now();
    let mut loss_curve: Vec<f64> = Vec::with_capacity(steps);

    for step in 0..steps {
        // Synchronous iteration: snapshot X_t, every node steps from it.
        let snapshot = xs.clone();
        let mut losses = Vec::with_capacity(n_nodes);
        for i in 0..n_nodes {
            let left = &snapshot[(i + n_nodes - 1) % n_nodes];
            let right = &snapshot[(i + 1) % n_nodes];
            let mut neighbors = Vec::with_capacity(2 * m.padded_dim);
            neighbors.extend_from_slice(left);
            neighbors.extend_from_slice(right);
            let tokens = samplers[i].sample(&mut rngs[i]);
            let out = engine.dcd_step(
                &snapshot[i],
                &neighbors,
                &weights,
                gamma,
                &tokens,
                (step * n_nodes + i) as i32,
            )?;
            losses.push(out.loss as f64);
            // The wire: bit-packed levels + scales, to each of 2
            // neighbors. In this in-process driver the neighbors read the
            // same x_new (replica ≡ model invariant of DCD).
            bytes_sent += 2 * q8_wire as u64;
            xs[i] = out.x_new;
        }
        let mean_loss: f64 = losses.iter().sum::<f64>() / n_nodes as f64;
        loss_curve.push(mean_loss);
        if step % log_every == 0 || step + 1 == steps {
            let consensus = decomp::algorithms::consensus_distance(&xs);
            table.row(vec![
                step.to_string(),
                format!("{mean_loss:.4}"),
                format!("{consensus:.3e}"),
                fmt_bytes(bytes_sent as f64),
            ]);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    table.print();

    let k = 10.min(loss_curve.len());
    let first: f64 = loss_curve[..k].iter().sum::<f64>() / k as f64;
    let last: f64 = loss_curve[loss_curve.len() - k..].iter().sum::<f64>() / k as f64;
    println!(
        "\nloss {first:.4} -> {last:.4} over {steps} steps | wall {wall:.1}s \
         ({:.0}ms/node-step) | wire sent {} (fp32 would be {}, saving {:.1}x)",
        wall * 1e3 / (steps * n_nodes) as f64,
        fmt_bytes(bytes_sent as f64),
        fmt_bytes((steps * n_nodes * 2 * fp_wire) as f64),
        fp_wire as f64 / q8_wire as f64,
    );
    anyhow::ensure!(last < first, "training should reduce loss");
    Ok(())
}
