//! Divergence demo: the two failure modes the paper's theory predicts.
//!
//!   cargo run --release --example divergence_demo
//!
//! 1. Fig. 1 — naive model compression in D-PSGD stalls at a noise floor
//!    while true D-PSGD anneals to the optimum.
//! 2. Theorem 1's admissibility bound — DCD-PSGD *diverges* once the
//!    compressor's α exceeds (1−ρ)/(2µ), while ECD-PSGD stays bounded
//!    under the identical compressor (§4.2's robustness claim).

use decomp::algorithms::{self, AlgoConfig};
use decomp::compression::{empirical_alpha, from_name};
use decomp::experiments::fig1;
use decomp::metrics::Table;
use decomp::models::{GradientModel, Quadratic};
use decomp::topology::{Graph, MixingMatrix, Topology};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // Part 1: Fig. 1.
    for t in fig1::run(false) {
        t.print();
        println!();
    }

    // Part 2: DCD past its α bound vs ECD.
    let n = 8;
    let dim = 64;
    let mixing = Arc::new(MixingMatrix::uniform(Graph::build(Topology::Ring, n)));
    let bound = mixing.dcd_alpha_bound();
    let fam = Quadratic::family(n, dim, 1.0, 0.0, 0x51fe);
    let opt = Quadratic::optimum(&fam);
    let fstar: f64 = fam.iter().map(|q| q.full_loss(&opt)).sum::<f64>() / n as f64;

    let mut t = Table::new(
        &format!("DCD admissibility: ring n=8 has α bound {bound:.4} (Theorem 1)"),
        &["compressor", "alpha", "within_bound", "dcd_final_subopt", "ecd_final_subopt"],
    );
    for comp_name in ["q8", "q4", "sparse_p25", "sparse_p10", "sparse_p5"] {
        let comp = from_name(comp_name)
            .ok_or_else(|| anyhow::anyhow!("unknown compressor {comp_name}"))?;
        let alpha = empirical_alpha(comp.as_ref(), 2048, 6, 1);
        let subopt = |algo: &str| -> f64 {
            let mut models: Vec<Box<dyn GradientModel>> = fam
                .iter()
                .cloned()
                .map(|q| Box::new(q) as Box<dyn GradientModel>)
                .collect();
            let cfg = AlgoConfig {
                mixing: mixing.clone(),
                compressor: Arc::from(from_name(comp_name).unwrap()),
                seed: 0x51fe,
                eta: 1.0,
                link: None,
            };
            let x0 = vec![0.0f32; dim];
            let mut a = algorithms::from_name(algo, cfg, &x0, n).unwrap();
            for _ in 0..800 {
                a.step(&mut models, 0.05);
            }
            let mut mean = vec![0.0f32; dim];
            a.mean_params(&mut mean);
            fam.iter().map(|q| q.full_loss(&mean)).sum::<f64>() / n as f64 - fstar
        };
        t.row(vec![
            comp_name.into(),
            format!("{alpha:.3}"),
            if alpha < bound { "yes" } else { "NO" }.into(),
            format!("{:.3e}", subopt("dcd")),
            format!("{:.3e}", subopt("ecd")),
        ]);
    }
    t.print();
    println!(
        "\nReading: once alpha exceeds {bound:.4}, DCD blows up (inf/NaN) while ECD\n\
         stays bounded — the asymmetry §4.2 predicts. (sparse_p5 has alpha ≈ 4.4.)"
    );
    Ok(())
}
